//! Typed configuration schema: maps parsed TOML onto experiment/run
//! settings with validation and defaults. This is the launcher's config
//! surface (`energyucb run --config run.toml`).

use super::toml::{self, Value};
use crate::bandit::energyucb::{EnergyUcbConfig, InitStrategy};
use crate::bandit::RewardForm;
use crate::sim::freq::{FreqDomain, SwitchCost};

/// Which policy to construct.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyConfig {
    EnergyUcb(EnergyUcbConfig),
    ConstrainedEnergyUcb { ucb: EnergyUcbConfig, delta: f64 },
    Ucb1 { alpha: f64 },
    SwUcb { alpha: f64, lambda: f64, window: usize },
    EpsilonGreedy { eps0: f64, decay_c: f64 },
    EnergyTs,
    RoundRobin,
    Static { arm: usize },
    RlPower,
    DrlCap { mode: String },
    /// Contextual LinUCB over the serving feature vector
    /// ([`crate::bandit::LinUcb`]); the context dimension is pinned to
    /// [`crate::bandit::CONTEXT_DIM`].
    LinUcb { alpha: f64, ridge: f64 },
    /// QoS-constrained Contextual LinUCB ([`crate::bandit::CLinUcb`]):
    /// LinUCB scoring behind the slowdown-budget feasibility machinery.
    CLinUcb { alpha: f64, ridge: f64, delta: f64 },
    /// Fault-injection test policy: panics after `after` decisions
    /// ([`crate::bandit::PanicAfter`]). Config/wire-buildable so cluster
    /// chaos tests can crash a worker deterministically; deliberately
    /// undocumented in `energyucb list`.
    PanicAfter { after: u64 },
}

/// A full experiment/run configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Benchmarks to run (must be names from the calibrated suite).
    pub apps: Vec<String>,
    pub policy: PolicyConfig,
    pub reps: usize,
    pub seed: u64,
    pub dt_s: f64,
    pub reward_form: RewardForm,
    pub record_trace: bool,
    /// Output directory for CSV/JSON results.
    pub out_dir: String,
    /// Selectable frequency arms (`[freq] ghz = [...]`; defaults to the
    /// Aurora PVC domain). Must match the calibrated app tables' length
    /// (9 for the shipped suite) — validated where the app is known.
    pub freqs: FreqDomain,
    /// Per-transition DVFS cost (`[switch] latency_s / energy_j`; defaults
    /// to the paper's measured 150 µs / 0.3 J).
    pub switch_cost: SwitchCost,
    /// Inference-serving scenario (`[serving]` table): attaches a bursty
    /// arrival-process workload whose feature vector reaches contextual
    /// policies as per-step context. `None` = the classic context-free
    /// session.
    pub serving: Option<crate::workload::serving::ServingCfg>,
    /// Live-hardware backend selection (`[hw]` table): which driver
    /// `energyucb run --backend` defaults to, mock device count,
    /// safety-rail tuning, and scripted fault injection. `None` = the
    /// simulated backend.
    pub hw: Option<HwFileConfig>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            apps: vec!["tealeaf".into()],
            policy: PolicyConfig::EnergyUcb(EnergyUcbConfig::default()),
            reps: 1,
            seed: 0,
            dt_s: 0.01,
            reward_form: RewardForm::EnergyRatio,
            record_trace: false,
            out_dir: "results".into(),
            freqs: FreqDomain::aurora(),
            switch_cost: SwitchCost::default(),
            serving: None,
            hw: None,
        }
    }
}

/// Schema errors.
#[derive(Debug)]
pub enum ConfigError {
    Parse(toml::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // Parse is "transparent": Display already shows the inner parse
        // error, so exposing it as source() too would print it twice in
        // chained (`{err:#}`) output.
        None
    }
}

impl From<toml::ParseError> for ConfigError {
    fn from(e: toml::ParseError) -> ConfigError {
        ConfigError::Parse(e)
    }
}

fn invalid<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError::Invalid(msg.into()))
}

impl ExperimentConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, ConfigError> {
        let root = toml::parse(text)?;
        Self::from_value(&root)
    }

    pub fn from_value(root: &Value) -> Result<ExperimentConfig, ConfigError> {
        let mut cfg = ExperimentConfig::default();
        if let Some(apps) = root.get("apps") {
            let arr = apps
                .as_array()
                .ok_or_else(|| ConfigError::Invalid("apps must be an array".into()))?;
            cfg.apps = arr
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| ConfigError::Invalid("apps must be strings".into()))?;
        }
        for app in &cfg.apps {
            if crate::workload::calibration::app(app).is_none() {
                return invalid(format!("unknown app: {app}"));
            }
        }
        if let Some(v) = root.get_int("reps") {
            if v < 1 {
                return invalid("reps must be >= 1");
            }
            cfg.reps = v as usize;
        }
        if let Some(v) = root.get_int("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = root.get_float("dt_s") {
            if v <= 0.0 || v > 1.0 {
                return invalid("dt_s must be in (0, 1]");
            }
            cfg.dt_s = v;
        }
        if let Some(v) = root.get_bool("record_trace") {
            cfg.record_trace = v;
        }
        if let Some(v) = root.get_str("out_dir") {
            cfg.out_dir = v.to_string();
        }
        if let Some(v) = root.get_str("reward_form") {
            cfg.reward_form = match v {
                "E*R" => RewardForm::EnergyRatio,
                "E^2*R" => RewardForm::EnergySquaredRatio,
                "E*R^2" => RewardForm::EnergyRatioSquared,
                other => return invalid(format!("unknown reward_form: {other}")),
            };
        }
        if let Some(freq) = root.get("freq") {
            let Some(arr) = freq.get("ghz").and_then(Value::as_array) else {
                return invalid("[freq] requires a ghz array");
            };
            let ghz = arr
                .iter()
                .map(|v| v.as_float())
                .collect::<Option<Vec<f64>>>()
                .ok_or_else(|| ConfigError::Invalid("freq.ghz: numbers only".into()))?;
            cfg.freqs = FreqDomain::try_new(ghz)
                .map_err(|e| ConfigError::Invalid(format!("freq.ghz: {e}")))?;
        }
        if let Some(v) = root.get_float("switch.latency_s") {
            // Must fit inside one decision interval: a stall >= dt_s would
            // make the switching step's useful time non-positive.
            if v < 0.0 || v >= cfg.dt_s {
                return invalid(format!(
                    "switch.latency_s must be in [0, dt_s = {})",
                    cfg.dt_s
                ));
            }
            cfg.switch_cost.latency_s = v;
        }
        if let Some(v) = root.get_float("switch.energy_j") {
            if v < 0.0 {
                return invalid("switch.energy_j must be >= 0");
            }
            cfg.switch_cost.energy_j = v;
        }
        if let Some(s) = root.get("serving") {
            if s.as_table().is_none() {
                return invalid("[serving] must be a table");
            }
            cfg.serving = Some(parse_serving(s)?);
        }
        if let Some(h) = root.get("hw") {
            if h.as_table().is_none() {
                return invalid("[hw] must be a table");
            }
            cfg.hw = Some(parse_hw(h)?);
        }
        if root.get_str("policy.name").is_some() {
            cfg.policy = PolicyConfig::from_value(root.get("policy").unwrap())?;
        }
        Ok(cfg)
    }

    /// Instantiate the configured policy.
    pub fn build_policy(&self, k: usize, seed: u64) -> Box<dyn crate::bandit::Policy> {
        self.policy.build(k, seed)
    }
}

/// Parse and validate a `[serving]` table into a [`ServingCfg`]. The
/// checks mirror `ServingModel::new`'s asserts so a bad config surfaces
/// as a [`ConfigError`] instead of a panic.
fn parse_serving(
    s: &Value,
) -> Result<crate::workload::serving::ServingCfg, ConfigError> {
    let mut c = crate::workload::serving::ServingCfg::default();
    if let Some(v) = s.get_float("base_rate") {
        if v <= 0.0 {
            return invalid("serving.base_rate must be > 0");
        }
        c.base_rate = v;
    }
    if let Some(v) = s.get_int("diurnal_period") {
        if v < 1 {
            return invalid("serving.diurnal_period must be >= 1");
        }
        c.diurnal_period = v as u64;
    }
    if let Some(v) = s.get_float("diurnal_amp") {
        if !(0.0..1.0).contains(&v) {
            return invalid("serving.diurnal_amp must be in [0, 1)");
        }
        c.diurnal_amp = v;
    }
    if let Some(v) = s.get_float("burst_prob") {
        if !(0.0..1.0).contains(&v) {
            return invalid("serving.burst_prob must be in [0, 1)");
        }
        c.burst_prob = v;
    }
    if let Some(v) = s.get_float("burst_mean") {
        if v < 1.0 {
            return invalid("serving.burst_mean must be >= 1");
        }
        c.burst_mean = v;
    }
    if let Some(v) = s.get_float("burst_boost") {
        if v < 1.0 {
            return invalid("serving.burst_boost must be >= 1");
        }
        c.burst_boost = v;
    }
    if let Some(v) = s.get_float("tokens_per_req") {
        if v <= 0.0 {
            return invalid("serving.tokens_per_req must be > 0");
        }
        c.tokens_per_req = v;
    }
    if let Some(v) = s.get_float("capacity_tokens") {
        if v <= 0.0 {
            return invalid("serving.capacity_tokens must be > 0");
        }
        c.capacity_tokens = v;
    }
    if let Some(v) = s.get_float("ttft_budget") {
        if v <= 0.0 {
            return invalid("serving.ttft_budget must be > 0");
        }
        c.ttft_budget = v;
    }
    if let Some(v) = s.get_int("seed") {
        if v < 0 {
            return invalid("serving.seed must be >= 0");
        }
        c.seed = v as u64;
    }
    Ok(c)
}

/// `[hw]` table: live-hardware backend selection and safety-rail tuning
/// for `energyucb run --backend` / `energyucb devices` (EXPERIMENTS.md
/// §Live hardware).
#[derive(Clone, Debug, PartialEq)]
pub struct HwFileConfig {
    /// Backend the CLI defaults to: `"sim"`, `"mock"`, or `"nvml"`.
    pub backend: String,
    /// Mock device count (the nvml driver enumerates the host instead).
    pub devices: usize,
    /// Minimum decision intervals a device must dwell on a clock before
    /// the backend forwards the next switch to the driver.
    pub min_dwell_steps: u64,
    /// Consecutive driver errors before a device degrades to its
    /// frozen-arm fallback.
    pub watchdog_errors: u32,
    /// Scripted mock faults, `kind@call[/dev]` grammar
    /// ([`crate::hw::parse_fault`]).
    pub faults: Vec<String>,
}

impl Default for HwFileConfig {
    fn default() -> Self {
        HwFileConfig {
            backend: "mock".into(),
            devices: 1,
            min_dwell_steps: 1,
            watchdog_errors: 3,
            faults: Vec::new(),
        }
    }
}

impl HwFileConfig {
    /// The fault specs as hw-layer faults. Infallible after a successful
    /// parse (`parse_hw` validated each spec), but re-validated here so
    /// hand-built configs fail loudly too.
    pub fn parsed_faults(&self) -> Result<Vec<crate::hw::Fault>, String> {
        self.faults.iter().map(|s| crate::hw::parse_fault(s)).collect()
    }
}

/// Parse and validate an `[hw]` table. Fault specs are parsed eagerly so
/// a typo fails at config load, not mid-run.
fn parse_hw(h: &Value) -> Result<HwFileConfig, ConfigError> {
    let mut c = HwFileConfig::default();
    if let Some(v) = h.get_str("backend") {
        match v {
            "sim" | "mock" | "nvml" => c.backend = v.to_string(),
            other => {
                return invalid(format!("hw.backend must be sim|mock|nvml, got {other}"))
            }
        }
    }
    if let Some(v) = h.get_int("devices") {
        if v < 1 {
            return invalid("hw.devices must be >= 1");
        }
        c.devices = v as usize;
    }
    if let Some(v) = h.get_int("min_dwell_steps") {
        if v < 1 {
            return invalid("hw.min_dwell_steps must be >= 1");
        }
        c.min_dwell_steps = v as u64;
    }
    if let Some(v) = h.get_int("watchdog_errors") {
        if v < 1 {
            return invalid("hw.watchdog_errors must be >= 1");
        }
        c.watchdog_errors = v as u32;
    }
    if let Some(arr) = h.get("faults") {
        let arr = arr
            .as_array()
            .ok_or_else(|| ConfigError::Invalid("hw.faults must be an array".into()))?;
        c.faults = arr
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| ConfigError::Invalid("hw.faults must be strings".into()))?;
        for f in &c.faults {
            crate::hw::parse_fault(f)
                .map_err(|e| ConfigError::Invalid(format!("hw.faults: {e}")))?;
        }
    }
    if c.backend == "nvml" && !c.faults.is_empty() {
        return invalid("hw.faults only applies to the mock backend");
    }
    Ok(c)
}

impl PolicyConfig {
    /// Parse from a policy table (`name` plus hyperparameter keys) — the
    /// payload of `[policy]`, a `[cluster.policy]` default, or a per-app
    /// `[cluster.scenario.policy]` override.
    pub fn from_value(tbl: &Value) -> Result<PolicyConfig, ConfigError> {
        let Some(name) = tbl.get_str("name") else {
            return invalid("policy table missing `name`");
        };
        let ucb_cfg = |tbl: &Value| -> Result<EnergyUcbConfig, ConfigError> {
            let mut c = EnergyUcbConfig::default();
            if let Some(v) = tbl.get_float("alpha") {
                if v < 0.0 {
                    return invalid("alpha must be >= 0");
                }
                c.alpha = v;
            }
            if let Some(v) = tbl.get_float("lambda") {
                if v < 0.0 {
                    return invalid("lambda must be >= 0");
                }
                c.lambda = v;
            }
            if let Some(v) = tbl.get_float("mu_init") {
                c.mu_init = v;
            }
            if let Some(v) = tbl.get_float("prior_n") {
                c.prior_n = v;
            }
            if let Some(v) = tbl.get_float("discount") {
                if v <= 0.0 || v > 1.0 {
                    return invalid("discount must be in (0, 1]");
                }
                c.discount = v;
            }
            if let Some(v) = tbl.get_str("init") {
                c.init = match v {
                    "optimistic" => InitStrategy::Optimistic,
                    "warmup" => InitStrategy::WarmupRoundRobin,
                    other => return invalid(format!("unknown init: {other}")),
                };
            }
            Ok(c)
        };
        Ok(match name {
            "energyucb" => PolicyConfig::EnergyUcb(ucb_cfg(tbl)?),
            "constrained" => {
                let delta = tbl.get_float("delta").unwrap_or(0.05);
                if !(0.0..1.0).contains(&delta) {
                    return invalid("delta must be in [0, 1)");
                }
                PolicyConfig::ConstrainedEnergyUcb { ucb: ucb_cfg(tbl)?, delta }
            }
            "ucb1" => PolicyConfig::Ucb1 { alpha: tbl.get_float("alpha").unwrap_or(0.05) },
            "swucb" => {
                let window = tbl.get_int("window").unwrap_or(500);
                if window < 1 {
                    return invalid("swucb window must be >= 1");
                }
                PolicyConfig::SwUcb {
                    alpha: tbl.get_float("alpha").unwrap_or(0.05),
                    lambda: tbl.get_float("lambda").unwrap_or(0.01),
                    window: window as usize,
                }
            }
            "egreedy" => PolicyConfig::EpsilonGreedy {
                eps0: tbl.get_float("eps0").unwrap_or(0.1),
                decay_c: tbl.get_float("decay_c").unwrap_or(20.0),
            },
            "energyts" => PolicyConfig::EnergyTs,
            "rrfreq" => PolicyConfig::RoundRobin,
            "static" => {
                let arm = tbl.get_int("arm").unwrap_or(8);
                if !(0..9).contains(&arm) {
                    return invalid("static arm must be in 0..9");
                }
                PolicyConfig::Static { arm: arm as usize }
            }
            "linucb" | "clinucb" => {
                let alpha = tbl.get_float("alpha").unwrap_or(1.0);
                if alpha < 0.0 {
                    return invalid("alpha must be >= 0");
                }
                let ridge = tbl.get_float("ridge").unwrap_or(1.0);
                if ridge <= 0.0 {
                    return invalid("ridge must be > 0");
                }
                if name == "linucb" {
                    PolicyConfig::LinUcb { alpha, ridge }
                } else {
                    let delta = tbl.get_float("delta").unwrap_or(0.05);
                    if !(0.0..1.0).contains(&delta) {
                        return invalid("delta must be in [0, 1)");
                    }
                    PolicyConfig::CLinUcb { alpha, ridge, delta }
                }
            }
            "rlpower" => PolicyConfig::RlPower,
            "drlcap" => PolicyConfig::DrlCap {
                mode: tbl.get_str("mode").unwrap_or("pretrain").to_string(),
            },
            "panicafter" => {
                let after = tbl.get_int("after").unwrap_or(100);
                if after < 0 {
                    return invalid("panicafter `after` must be >= 0");
                }
                PolicyConfig::PanicAfter { after: after as u64 }
            }
            other => return invalid(format!("unknown policy: {other}")),
        })
    }

    /// Instantiate this policy.
    pub fn build(&self, k: usize, seed: u64) -> Box<dyn crate::bandit::Policy> {
        use crate::bandit::*;
        use crate::rl::{DrlCap, DrlCapMode, RlPower};
        match self {
            PolicyConfig::EnergyUcb(c) => Box::new(EnergyUcb::new(k, *c)),
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta } => {
                Box::new(ConstrainedEnergyUcb::new(k, *ucb, *delta))
            }
            PolicyConfig::Ucb1 { alpha } => Box::new(Ucb1::new(k, *alpha)),
            PolicyConfig::SwUcb { alpha, lambda, window } => {
                Box::new(SlidingWindowUcb::new(k, *alpha, *lambda, *window))
            }
            PolicyConfig::EpsilonGreedy { eps0, decay_c } => {
                Box::new(EpsilonGreedy::new(k, *eps0, *decay_c, seed))
            }
            PolicyConfig::EnergyTs => Box::new(EnergyTs::default_for(k, seed)),
            PolicyConfig::RoundRobin => Box::new(RoundRobin::new(k)),
            PolicyConfig::Static { arm } => Box::new(StaticPolicy::new(k, *arm)),
            PolicyConfig::RlPower => Box::new(RlPower::new(k, seed)),
            PolicyConfig::DrlCap { mode } => {
                let m = match mode.as_str() {
                    "online" => DrlCapMode::Online,
                    "cross" => DrlCapMode::CrossDeploy,
                    _ => DrlCapMode::PretrainDeploy,
                };
                Box::new(DrlCap::new(k, m, seed))
            }
            PolicyConfig::PanicAfter { after } => Box::new(PanicAfter::new(k, *after)),
            PolicyConfig::LinUcb { alpha, ridge } => {
                Box::new(LinUcb::new(k, CONTEXT_DIM, *alpha, *ridge))
            }
            PolicyConfig::CLinUcb { alpha, ridge, delta } => {
                Box::new(CLinUcb::new(k, CONTEXT_DIM, *alpha, *ridge, *delta))
            }
        }
    }

    /// Instantiate this policy batched over `b` environments: a native SoA
    /// implementation where one exists (EnergyUCB/SA-UCB and its
    /// constrained variant on their fleet contract — optimistic init, no
    /// discounting —, UCB1, SW-UCB, ε-greedy), the
    /// [`Scalar`][crate::bandit::Scalar] bridge of `b` scalar instances
    /// (seeded `seed + e`) everywhere else. SA-UCB environments start
    /// pinned to the default-frequency arm K-1, matching
    /// `FleetState::fresh`.
    pub fn build_batch(&self, b: usize, k: usize, seed: u64) -> Box<dyn crate::bandit::BatchPolicy> {
        use crate::bandit::batch::{
            BatchConstrainedEnergyUcb, BatchEnergyUcb, BatchEpsilonGreedy, BatchSwUcb, BatchUcb1,
            SaUcbHyper, Scalar,
        };
        match self {
            PolicyConfig::EnergyUcb(c)
                if c.discount == 1.0 && c.init == InitStrategy::Optimistic =>
            {
                Box::new(BatchEnergyUcb::with_initial_arm(b, k, SaUcbHyper::from(c), k - 1))
            }
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta }
                if ucb.discount == 1.0 && ucb.init == InitStrategy::Optimistic =>
            {
                Box::new(BatchConstrainedEnergyUcb::with_initial_arm(
                    b,
                    k,
                    SaUcbHyper::from(ucb),
                    *delta as f32,
                    k - 1,
                ))
            }
            PolicyConfig::Ucb1 { alpha } => Box::new(BatchUcb1::new(b, k, *alpha)),
            PolicyConfig::SwUcb { alpha, lambda, window } => {
                Box::new(BatchSwUcb::new(b, k, *alpha, *lambda, *window))
            }
            PolicyConfig::EpsilonGreedy { eps0, decay_c } => {
                Box::new(BatchEpsilonGreedy::new(b, k, *eps0, *decay_c, seed))
            }
            PolicyConfig::LinUcb { alpha, ridge } => Box::new(crate::bandit::BatchLinUcb::new(
                b,
                k,
                crate::bandit::CONTEXT_DIM,
                *alpha,
                *ridge,
            )),
            PolicyConfig::CLinUcb { alpha, ridge, delta } => {
                Box::new(crate::bandit::BatchCLinUcb::new(
                    b,
                    k,
                    crate::bandit::CONTEXT_DIM,
                    *alpha,
                    *ridge,
                    *delta,
                ))
            }
            // Everything else (Thompson, static, round-robin, RL baselines,
            // warmup/discount ablation configurations) rides the bridge.
            other => Box::new(Scalar::new(
                (0..b)
                    .map(|e| other.build(k, seed.wrapping_add(e as u64)))
                    .collect::<Vec<_>>(),
            )),
        }
    }

    /// Whether [`build_batch`](Self::build_batch) yields a native SoA
    /// implementation that honors the (B, K) feasibility mask.
    /// Bridge-backed policies ignore the mask (scalar policies own their
    /// feasibility), so callers constraining a fleet through
    /// `FleetParams::feasible` (e.g. `fleet --delta`) must check this.
    pub fn batch_honors_mask(&self) -> bool {
        match self {
            PolicyConfig::EnergyUcb(c) | PolicyConfig::ConstrainedEnergyUcb { ucb: c, .. } => {
                c.discount == 1.0 && c.init == InitStrategy::Optimistic
            }
            PolicyConfig::Ucb1 { .. }
            | PolicyConfig::SwUcb { .. }
            | PolicyConfig::EpsilonGreedy { .. }
            | PolicyConfig::LinUcb { .. }
            | PolicyConfig::CLinUcb { .. } => true,
            _ => false,
        }
    }
}

/// `energyucb cluster` file configuration: the `[cluster]` table plus the
/// `[[cluster.scenario]]` app-mix entries.
///
/// ```toml
/// [cluster]
/// nodes = 64
/// seed = 2026
/// heartbeat_steps = 1000
/// shards = 2                  # optional: K worker shards (JSONL wire)
/// transport = "tcp"           # optional: in-process|subprocess|tcp
/// listen = "127.0.0.1:0"      # optional: TCP listen address
/// shard_timeout_s = 120.0     # optional: per-shard read deadline
/// shard_retries = 2           # optional: dead-shard requeue budget
/// preset = "mixed"            # optional base: uniform|mixed|staggered|hetero|chaos
/// pick = "weighted"           # or "round_robin"
///
/// [cluster.policy]            # fleet-wide default policy
/// name = "energyucb"
///
/// [cluster.arrivals]          # staggered arrivals (step budgets)
/// phases = 4
/// min_frac = 0.25
/// base_steps = 6000
///
/// [cluster.hetero]            # per-node switch-cost choices (paired)
/// latency_s = [0.00015, 0.0006]
/// energy_j = [0.3, 1.8]
///
/// [[cluster.scenario]]        # app mix (replaces the preset's slots)
/// app = "tealeaf"
/// weight = 3.0
///
/// [[cluster.scenario]]
/// app = "lbm"
/// [cluster.scenario.policy]   # per-app policy override
/// name = "static"
/// arm = 7
/// ```
#[derive(Clone, Debug)]
pub struct ClusterFileConfig {
    pub nodes: usize,
    /// Worker threads; `None` = CLI/default decides.
    pub jobs: Option<usize>,
    /// Subprocess shard count (`shards = K` / `--shards K`); `None` = the
    /// in-process pool. Reports are byte-identical either way
    /// (EXPERIMENTS.md §Cluster).
    pub shards: Option<usize>,
    /// Shard transport (`transport = "in-process" | "subprocess" | "tcp"`);
    /// `None` = CLI/default decides (subprocess when shards are set).
    pub transport: Option<String>,
    /// TCP listen address for `transport = "tcp"` (`listen =
    /// "HOST:PORT"`); `None` = an ephemeral loopback port.
    pub listen: Option<String>,
    /// Per-shard read deadline, seconds: a worker that sends no frame for
    /// this long is declared dead and its shard requeued. `None` = the
    /// CLI default (120 s).
    pub shard_timeout_s: Option<f64>,
    /// How many times a shard whose worker died may be requeued before
    /// the run aborts (`shard_retries = N` / `--shard-retries N`; 0 =
    /// fail fast on the first death). `None` = the leader default (2).
    pub shard_retries: Option<usize>,
    pub heartbeat_steps: u64,
    /// Fleet-wide default policy (per-app overrides ride on the slots).
    pub policy: PolicyConfig,
    pub schedule: crate::cluster::ScenarioSchedule,
}

impl Default for ClusterFileConfig {
    fn default() -> Self {
        ClusterFileConfig {
            nodes: 16,
            jobs: None,
            shards: None,
            transport: None,
            listen: None,
            shard_timeout_s: None,
            shard_retries: None,
            heartbeat_steps: 1_000,
            policy: PolicyConfig::EnergyUcb(EnergyUcbConfig::default()),
            schedule: crate::cluster::ScenarioSchedule::preset("uniform", 2026)
                .expect("uniform preset exists"),
        }
    }
}

impl ClusterFileConfig {
    pub fn from_toml(text: &str) -> Result<ClusterFileConfig, ConfigError> {
        let root = toml::parse(text)?;
        Self::from_value(&root)
    }

    pub fn from_value(root: &Value) -> Result<ClusterFileConfig, ConfigError> {
        use crate::cluster::{AppSlot, Arrivals, Pick, ScenarioSchedule};
        let mut cfg = ClusterFileConfig::default();
        let Some(c) = root.get("cluster") else {
            return Ok(cfg);
        };
        if c.as_table().is_none() {
            return invalid("[cluster] must be a table");
        }
        let seed = match c.get_int("seed") {
            Some(v) if v < 0 => return invalid("cluster.seed must be >= 0"),
            Some(v) => v as u64,
            None => cfg.schedule.seed,
        };
        if let Some(name) = c.get_str("preset") {
            cfg.schedule = ScenarioSchedule::preset(name, seed)
                .ok_or_else(|| ConfigError::Invalid(format!("unknown preset: {name}")))?;
        }
        cfg.schedule.seed = seed;
        if let Some(v) = c.get_int("nodes") {
            if v < 1 {
                return invalid("cluster.nodes must be >= 1");
            }
            cfg.nodes = v as usize;
        }
        if let Some(v) = c.get_int("jobs") {
            if v < 1 {
                return invalid("cluster.jobs must be >= 1");
            }
            cfg.jobs = Some(v as usize);
        }
        if let Some(v) = c.get_int("shards") {
            if v < 1 {
                return invalid("cluster.shards must be >= 1");
            }
            cfg.shards = Some(v as usize);
        }
        if let Some(v) = c.get_str("transport") {
            if !matches!(v, "in-process" | "subprocess" | "tcp") {
                return invalid(format!(
                    "cluster.transport must be in-process|subprocess|tcp, got: {v}"
                ));
            }
            cfg.transport = Some(v.to_string());
        }
        if let Some(v) = c.get_str("listen") {
            cfg.listen = Some(v.to_string());
        }
        if let Some(v) = c.get_float("shard_timeout_s") {
            if !(v > 0.0) {
                return invalid("cluster.shard_timeout_s must be > 0");
            }
            cfg.shard_timeout_s = Some(v);
        }
        if let Some(v) = c.get_int("shard_retries") {
            if v < 0 {
                return invalid("cluster.shard_retries must be >= 0");
            }
            cfg.shard_retries = Some(v as usize);
        }
        if let Some(v) = c.get_int("heartbeat_steps") {
            if v < 1 {
                return invalid("cluster.heartbeat_steps must be >= 1");
            }
            cfg.heartbeat_steps = v as u64;
        }
        if c.get_str("policy.name").is_some() {
            cfg.policy = PolicyConfig::from_value(c.get("policy").unwrap())?;
        }
        if let Some(v) = c.get_str("pick") {
            cfg.schedule.pick = match v {
                "round_robin" => Pick::RoundRobin,
                "weighted" => Pick::Weighted,
                other => return invalid(format!("unknown pick: {other}")),
            };
        }
        if let Some(arr) = c.get("arrivals") {
            let phases = arr.get_int("phases").unwrap_or(4);
            let min_frac = arr.get_float("min_frac").unwrap_or(0.25);
            let base_steps = arr.get_int("base_steps").unwrap_or(6_000);
            if phases < 1 || base_steps < 1 {
                return invalid("cluster.arrivals: phases and base_steps must be >= 1");
            }
            if !(min_frac > 0.0 && min_frac <= 1.0) {
                return invalid("cluster.arrivals.min_frac must be in (0, 1]");
            }
            cfg.schedule.arrivals = Arrivals::Staggered {
                phases: phases as usize,
                min_frac,
                base_steps: base_steps as u64,
            };
        }
        if let Some(h) = c.get("hetero") {
            let floats = |key: &str| -> Result<Vec<f64>, ConfigError> {
                h.get(key)
                    .and_then(Value::as_array)
                    .ok_or_else(|| {
                        ConfigError::Invalid(format!("cluster.hetero.{key} must be an array"))
                    })?
                    .iter()
                    .map(|v| {
                        v.as_float().ok_or_else(|| {
                            ConfigError::Invalid(format!("cluster.hetero.{key}: numbers only"))
                        })
                    })
                    .collect()
            };
            let latency = floats("latency_s")?;
            let energy = floats("energy_j")?;
            if latency.len() != energy.len() || latency.is_empty() {
                return invalid("cluster.hetero: latency_s and energy_j must pair up");
            }
            cfg.schedule.switch_costs = latency
                .into_iter()
                .zip(energy)
                .map(|(latency_s, energy_j)| {
                    if latency_s < 0.0 || energy_j < 0.0 {
                        return invalid("cluster.hetero: costs must be >= 0");
                    }
                    Ok(SwitchCost { latency_s, energy_j })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(scen) = c.get("scenario") {
            let Some(entries) = scen.as_array() else {
                return invalid("cluster.scenario must be an array of tables ([[cluster.scenario]])");
            };
            let mut slots = Vec::new();
            for entry in entries {
                let Some(app) = entry.get_str("app") else {
                    return invalid("[[cluster.scenario]] entry missing `app`");
                };
                let mut slot = AppSlot::new(app);
                if let Some(w) = entry.get_float("weight") {
                    slot.weight = w;
                }
                if entry.get_str("policy.name").is_some() {
                    slot.policy = Some(PolicyConfig::from_value(entry.get("policy").unwrap())?);
                }
                slots.push(slot);
            }
            cfg.schedule.slots = slots;
            cfg.schedule.name = "custom".into();
        }
        cfg.schedule.validate().map_err(ConfigError::Invalid)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.reps, 1);
        assert_eq!(c.apps, vec!["tealeaf".to_string()]);
        assert!(matches!(c.policy, PolicyConfig::EnergyUcb(_)));
    }

    #[test]
    fn full_config_parses() {
        let text = r#"
apps = ["llama", "sph_exa"]
reps = 10
seed = 42
reward_form = "E*R"

[policy]
name = "constrained"
alpha = 0.07
lambda = 0.02
delta = 0.05
"#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.apps.len(), 2);
        assert_eq!(c.reps, 10);
        match &c.policy {
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta } => {
                assert!((ucb.alpha - 0.07).abs() < 1e-12);
                assert!((ucb.lambda - 0.02).abs() < 1e-12);
                assert!((delta - 0.05).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_app() {
        assert!(ExperimentConfig::from_toml("apps = [\"nope\"]").is_err());
    }

    #[test]
    fn rejects_bad_hyperparams() {
        let bad = "
[policy]
name = \"energyucb\"
alpha = -1.0
";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        assert!(ExperimentConfig::from_toml("dt_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"bogus\"").is_err());
    }

    #[test]
    fn builds_each_policy_kind() {
        for name in [
            "energyucb",
            "constrained",
            "ucb1",
            "swucb",
            "egreedy",
            "energyts",
            "rrfreq",
            "static",
            "rlpower",
            "drlcap",
            "linucb",
            "clinucb",
        ] {
            let text = format!("[policy]\nname = \"{name}\"");
            let c = ExperimentConfig::from_toml(&text).unwrap();
            let p = c.build_policy(9, 1);
            assert_eq!(p.k(), 9, "{name}");
            // And every configuration is batch-constructible too.
            let bp = c.policy.build_batch(4, 9, 1);
            assert_eq!(bp.k(), 9, "{name} batched");
            assert_eq!(bp.b(), 4, "{name} batched");
        }
    }

    #[test]
    fn swucb_config_parses_and_validates() {
        let text = "[policy]\nname = \"swucb\"\nalpha = 0.1\nwindow = 300";
        let c = ExperimentConfig::from_toml(text).unwrap();
        match c.policy {
            PolicyConfig::SwUcb { alpha, lambda, window } => {
                assert!((alpha - 0.1).abs() < 1e-12);
                assert!((lambda - 0.01).abs() < 1e-12);
                assert_eq!(window, 300);
            }
            other => panic!("{other:?}"),
        }
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"swucb\"\nwindow = 0").is_err());
    }

    #[test]
    fn freq_domain_parses_and_validates() {
        let text = "[freq]\nghz = [0.9, 1.1, 1.3]\n";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.freqs.k(), 3);
        assert!((c.freqs.ghz(0) - 0.9).abs() < 1e-12);
        assert!((c.freqs.max_ghz() - 1.3).abs() < 1e-12);
        // Defaults to Aurora when absent.
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.freqs, FreqDomain::aurora());
        // Invalid domains are config errors, not panics.
        assert!(ExperimentConfig::from_toml("[freq]\nghz = []").is_err());
        assert!(ExperimentConfig::from_toml("[freq]\nghz = [1.0, 0.9]").is_err());
        assert!(ExperimentConfig::from_toml("[freq]\nghz = [-1.0]").is_err());
        assert!(ExperimentConfig::from_toml("[freq]\nghz = [\"a\"]").is_err());
        assert!(ExperimentConfig::from_toml("[freq]\nother = 1").is_err());
    }

    #[test]
    fn switch_cost_parses_and_validates() {
        let text = "[switch]\nlatency_s = 0.0003\nenergy_j = 1.2\n";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert!((c.switch_cost.latency_s - 300e-6).abs() < 1e-12);
        assert!((c.switch_cost.energy_j - 1.2).abs() < 1e-12);
        // Defaults when absent.
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.switch_cost, SwitchCost::default());
        assert!(ExperimentConfig::from_toml("[switch]\nenergy_j = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[switch]\nlatency_s = 2.0").is_err());
        // A stall >= the decision interval would run progress backwards.
        assert!(ExperimentConfig::from_toml("[switch]\nlatency_s = 0.01").is_err());
        // ... unless dt_s is raised accordingly.
        assert!(ExperimentConfig::from_toml("dt_s = 0.1\n[switch]\nlatency_s = 0.01").is_ok());
    }

    #[test]
    fn cluster_config_defaults_when_absent() {
        let c = ClusterFileConfig::from_toml("").unwrap();
        assert_eq!(c.nodes, 16);
        assert_eq!(c.jobs, None);
        assert_eq!(c.shards, None);
        assert_eq!(c.schedule.name, "uniform");
    }

    #[test]
    fn cluster_config_full_parse() {
        use crate::cluster::{Arrivals, Pick};
        let text = r#"
[cluster]
nodes = 24
seed = 99
jobs = 4
shards = 3
heartbeat_steps = 500
pick = "weighted"

[cluster.policy]
name = "constrained"
delta = 0.1

[cluster.arrivals]
phases = 3
min_frac = 0.5
base_steps = 2000

[cluster.hetero]
latency_s = [0.00015, 0.0006]
energy_j = [0.3, 1.8]

[[cluster.scenario]]
app = "tealeaf"
weight = 2.0

[[cluster.scenario]]
app = "lbm"

[cluster.scenario.policy]
name = "static"
arm = 7
"#;
        let c = ClusterFileConfig::from_toml(text).unwrap();
        assert_eq!(c.nodes, 24);
        assert_eq!(c.jobs, Some(4));
        assert_eq!(c.shards, Some(3));
        assert_eq!(c.heartbeat_steps, 500);
        assert_eq!(c.schedule.seed, 99);
        assert_eq!(c.schedule.pick, Pick::Weighted);
        assert!(matches!(c.policy, PolicyConfig::ConstrainedEnergyUcb { .. }));
        assert_eq!(
            c.schedule.arrivals,
            Arrivals::Staggered { phases: 3, min_frac: 0.5, base_steps: 2000 }
        );
        assert_eq!(c.schedule.switch_costs.len(), 2);
        assert_eq!(c.schedule.switch_costs[1], SwitchCost { latency_s: 0.0006, energy_j: 1.8 });
        assert_eq!(c.schedule.slots.len(), 2);
        assert_eq!(c.schedule.slots[0].app, "tealeaf");
        assert!((c.schedule.slots[0].weight - 2.0).abs() < 1e-12);
        assert_eq!(c.schedule.slots[1].policy, Some(PolicyConfig::Static { arm: 7 }));
        // Assignments draw from the parsed scenario.
        let a = c.schedule.assignments(c.nodes).unwrap();
        assert_eq!(a.len(), 24);
        assert!(a.iter().all(|x| x.max_steps.is_some() && x.switch_cost.is_some()));
    }

    #[test]
    fn linucb_config_parses_and_validates() {
        let text = "[policy]\nname = \"linucb\"\nalpha = 0.4\nridge = 2.0";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.policy, PolicyConfig::LinUcb { alpha: 0.4, ridge: 2.0 });
        assert!(c.policy.batch_honors_mask());
        let text = "[policy]\nname = \"clinucb\"\ndelta = 0.1";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.policy, PolicyConfig::CLinUcb { alpha: 1.0, ridge: 1.0, delta: 0.1 });
        assert!(c.policy.batch_honors_mask());
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"linucb\"\nalpha = -0.1").is_err());
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"linucb\"\nridge = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"clinucb\"\ndelta = 1.0").is_err());
    }

    #[test]
    fn serving_table_parses_and_validates() {
        // Absent table: no serving scenario.
        assert_eq!(ExperimentConfig::from_toml("").unwrap().serving, None);
        let text = r#"
[serving]
base_rate = 8.0
diurnal_period = 500
diurnal_amp = 0.3
burst_prob = 0.05
ttft_budget = 1.5
seed = 7
"#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        let s = c.serving.unwrap();
        assert!((s.base_rate - 8.0).abs() < 1e-12);
        assert_eq!(s.diurnal_period, 500);
        assert!((s.diurnal_amp - 0.3).abs() < 1e-12);
        assert!((s.burst_prob - 0.05).abs() < 1e-12);
        assert!((s.ttft_budget - 1.5).abs() < 1e-12);
        assert_eq!(s.seed, 7);
        // Unset keys keep the defaults.
        let d = crate::workload::serving::ServingCfg::default();
        assert!((s.capacity_tokens - d.capacity_tokens).abs() < 1e-12);
        // An empty [serving] table is the default scenario.
        assert_eq!(
            ExperimentConfig::from_toml("[serving]\n").unwrap().serving,
            Some(d)
        );
        // Every range check is a config error, not a model panic.
        for bad in [
            "[serving]\nbase_rate = 0.0",
            "[serving]\ndiurnal_period = 0",
            "[serving]\ndiurnal_amp = 1.0",
            "[serving]\nburst_prob = 1.0",
            "[serving]\nburst_mean = 0.5",
            "[serving]\nburst_boost = 0.9",
            "[serving]\ntokens_per_req = -1.0",
            "[serving]\ncapacity_tokens = 0.0",
            "[serving]\nttft_budget = 0.0",
            "[serving]\nseed = -1",
        ] {
            assert!(ExperimentConfig::from_toml(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn cluster_shard_retries_parses_and_validates() {
        let c = ClusterFileConfig::from_toml("[cluster]\nshard_retries = 5").unwrap();
        assert_eq!(c.shard_retries, Some(5));
        // 0 = fail fast on the first worker death.
        let c = ClusterFileConfig::from_toml("[cluster]\nshard_retries = 0").unwrap();
        assert_eq!(c.shard_retries, Some(0));
        // Absent: the leader default decides.
        assert_eq!(ClusterFileConfig::from_toml("").unwrap().shard_retries, None);
        assert!(ClusterFileConfig::from_toml("[cluster]\nshard_retries = -1").is_err());
    }

    #[test]
    fn cluster_transport_fields_parse_and_validate() {
        let text = r#"
[cluster]
shards = 3
transport = "tcp"
listen = "127.0.0.1:7070"
shard_timeout_s = 2.5
"#;
        let c = ClusterFileConfig::from_toml(text).unwrap();
        assert_eq!(c.transport.as_deref(), Some("tcp"));
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(c.shard_timeout_s, Some(2.5));
        // Defaults when absent.
        let c = ClusterFileConfig::from_toml("").unwrap();
        assert_eq!(c.transport, None);
        assert_eq!(c.listen, None);
        assert_eq!(c.shard_timeout_s, None);
        // Bad values are config errors.
        assert!(ClusterFileConfig::from_toml("[cluster]\ntransport = \"carrier-pigeon\"").is_err());
        assert!(ClusterFileConfig::from_toml("[cluster]\nshard_timeout_s = 0.0").is_err());
        assert!(ClusterFileConfig::from_toml("[cluster]\nshard_timeout_s = -1.0").is_err());
    }

    #[test]
    fn panicafter_policy_parses_and_builds() {
        let text = "[policy]\nname = \"panicafter\"\nafter = 7";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.policy, PolicyConfig::PanicAfter { after: 7 });
        let mut p = c.build_policy(9, 0);
        assert_eq!(p.k(), 9);
        assert_eq!(p.select(1), 8); // behaves statically until the fault
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"panicafter\"\nafter = -1").is_err());
    }

    #[test]
    fn cluster_config_preset_base() {
        let c = ClusterFileConfig::from_toml("[cluster]\npreset = \"mixed\"\nseed = 5").unwrap();
        assert_eq!(c.schedule.name, "mixed");
        assert_eq!(c.schedule.seed, 5);
        assert!(ClusterFileConfig::from_toml("[cluster]\npreset = \"nope\"").is_err());
    }

    #[test]
    fn cluster_config_rejects_bad_input() {
        assert!(ClusterFileConfig::from_toml("[cluster]\nnodes = 0").is_err());
        assert!(ClusterFileConfig::from_toml("[cluster]\nshards = 0").is_err());
        assert!(ClusterFileConfig::from_toml("[cluster]\nseed = -1").is_err());
        assert!(ClusterFileConfig::from_toml("[[cluster.scenario]]\nweight = 1.0").is_err());
        assert!(
            ClusterFileConfig::from_toml("[[cluster.scenario]]\napp = \"not-an-app\"").is_err()
        );
        // Unpaired hetero arrays.
        assert!(ClusterFileConfig::from_toml(
            "[cluster.hetero]\nlatency_s = [0.1]\nenergy_j = [0.1, 0.2]"
        )
        .is_err());
        // Staggered fractions out of range.
        assert!(ClusterFileConfig::from_toml("[cluster.arrivals]\nmin_frac = 1.5").is_err());
    }

    #[test]
    fn hw_table_parses_validates_and_defaults() {
        let text = r#"
[hw]
backend = "mock"
devices = 2
min_dwell_steps = 4
watchdog_errors = 5
faults = ["reject@3", "lost@10/1"]
"#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        let hw = c.hw.unwrap();
        assert_eq!(hw.backend, "mock");
        assert_eq!(hw.devices, 2);
        assert_eq!(hw.min_dwell_steps, 4);
        assert_eq!(hw.watchdog_errors, 5);
        let faults = hw.parsed_faults().unwrap();
        assert_eq!(faults.len(), 2);
        assert_eq!(faults[1].device, 1);
        // Absent table → None; empty table → defaults.
        assert_eq!(ExperimentConfig::from_toml("").unwrap().hw, None);
        let d = ExperimentConfig::from_toml("[hw]").unwrap().hw.unwrap();
        assert_eq!(d, HwFileConfig::default());
        // Bad values are config errors, not mid-run surprises.
        assert!(ExperimentConfig::from_toml("[hw]\nbackend = \"fpga\"").is_err());
        assert!(ExperimentConfig::from_toml("[hw]\ndevices = 0").is_err());
        assert!(ExperimentConfig::from_toml("[hw]\nmin_dwell_steps = 0").is_err());
        assert!(ExperimentConfig::from_toml("[hw]\nwatchdog_errors = 0").is_err());
        assert!(ExperimentConfig::from_toml("[hw]\nfaults = [\"typo@\"]").is_err());
        assert!(ExperimentConfig::from_toml(
            "[hw]\nbackend = \"nvml\"\nfaults = [\"reject@1\"]"
        )
        .is_err());
    }

    #[test]
    fn warmup_init_parses() {
        let text = "[policy]\nname = \"energyucb\"\ninit = \"warmup\"";
        let c = ExperimentConfig::from_toml(text).unwrap();
        match c.policy {
            PolicyConfig::EnergyUcb(u) => assert_eq!(u.init, InitStrategy::WarmupRoundRobin),
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod shipped_config_tests {
    use super::*;

    /// The checked-in configs under configs/ must always parse and build.
    #[test]
    fn shipped_configs_parse_and_build() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        let mut seen = 0;
        for entry in std::fs::read_dir(dir).expect("configs/ exists") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("toml") {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            let cfg = ExperimentConfig::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let policy = cfg.build_policy(9, 1);
            assert_eq!(policy.k(), 9, "{}", path.display());
            // Cluster configs must also satisfy the cluster schema (a
            // no-op [cluster]-less file yields the defaults).
            ClusterFileConfig::from_toml(&text)
                .unwrap_or_else(|e| panic!("{} (cluster): {e}", path.display()));
            seen += 1;
        }
        assert!(seen >= 2, "expected shipped configs, found {seen}");
    }

    /// The shipped mixed-fleet scenario exercises every scenario feature.
    #[test]
    fn shipped_cluster_mixed_generates_assignments() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/cluster_mixed.toml");
        let text = std::fs::read_to_string(path).unwrap();
        let cfg = ClusterFileConfig::from_toml(&text).unwrap();
        assert_eq!(cfg.nodes, 64);
        assert_eq!(cfg.schedule.slots.len(), 5);
        let a = cfg.schedule.assignments(cfg.nodes).unwrap();
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|x| x.max_steps.is_some() && x.switch_cost.is_some()));
        assert!(a.iter().any(|x| x.policy.is_some()), "lbm static override missing");
    }
}
