//! Typed configuration schema: maps parsed TOML onto experiment/run
//! settings with validation and defaults. This is the launcher's config
//! surface (`energyucb run --config run.toml`).

use super::toml::{self, Value};
use crate::bandit::energyucb::{EnergyUcbConfig, InitStrategy};
use crate::bandit::RewardForm;
use crate::sim::freq::SwitchCost;

/// Which policy to construct.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyConfig {
    EnergyUcb(EnergyUcbConfig),
    ConstrainedEnergyUcb { ucb: EnergyUcbConfig, delta: f64 },
    Ucb1 { alpha: f64 },
    EpsilonGreedy { eps0: f64, decay_c: f64 },
    EnergyTs,
    RoundRobin,
    Static { arm: usize },
    RlPower,
    DrlCap { mode: String },
}

/// A full experiment/run configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Benchmarks to run (must be names from the calibrated suite).
    pub apps: Vec<String>,
    pub policy: PolicyConfig,
    pub reps: usize,
    pub seed: u64,
    pub dt_s: f64,
    pub reward_form: RewardForm,
    pub record_trace: bool,
    /// Output directory for CSV/JSON results.
    pub out_dir: String,
    /// Per-transition DVFS cost (`[switch] latency_s / energy_j`; defaults
    /// to the paper's measured 150 µs / 0.3 J).
    pub switch_cost: SwitchCost,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            apps: vec!["tealeaf".into()],
            policy: PolicyConfig::EnergyUcb(EnergyUcbConfig::default()),
            reps: 1,
            seed: 0,
            dt_s: 0.01,
            reward_form: RewardForm::EnergyRatio,
            record_trace: false,
            out_dir: "results".into(),
            switch_cost: SwitchCost::default(),
        }
    }
}

/// Schema errors.
#[derive(Debug)]
pub enum ConfigError {
    Parse(toml::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        // Parse is "transparent": Display already shows the inner parse
        // error, so exposing it as source() too would print it twice in
        // chained (`{err:#}`) output.
        None
    }
}

impl From<toml::ParseError> for ConfigError {
    fn from(e: toml::ParseError) -> ConfigError {
        ConfigError::Parse(e)
    }
}

fn invalid<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError::Invalid(msg.into()))
}

impl ExperimentConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig, ConfigError> {
        let root = toml::parse(text)?;
        Self::from_value(&root)
    }

    pub fn from_value(root: &Value) -> Result<ExperimentConfig, ConfigError> {
        let mut cfg = ExperimentConfig::default();
        if let Some(apps) = root.get("apps") {
            let arr = apps
                .as_array()
                .ok_or_else(|| ConfigError::Invalid("apps must be an array".into()))?;
            cfg.apps = arr
                .iter()
                .map(|v| v.as_str().map(str::to_string))
                .collect::<Option<Vec<_>>>()
                .ok_or_else(|| ConfigError::Invalid("apps must be strings".into()))?;
        }
        for app in &cfg.apps {
            if crate::workload::calibration::app(app).is_none() {
                return invalid(format!("unknown app: {app}"));
            }
        }
        if let Some(v) = root.get_int("reps") {
            if v < 1 {
                return invalid("reps must be >= 1");
            }
            cfg.reps = v as usize;
        }
        if let Some(v) = root.get_int("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = root.get_float("dt_s") {
            if v <= 0.0 || v > 1.0 {
                return invalid("dt_s must be in (0, 1]");
            }
            cfg.dt_s = v;
        }
        if let Some(v) = root.get_bool("record_trace") {
            cfg.record_trace = v;
        }
        if let Some(v) = root.get_str("out_dir") {
            cfg.out_dir = v.to_string();
        }
        if let Some(v) = root.get_str("reward_form") {
            cfg.reward_form = match v {
                "E*R" => RewardForm::EnergyRatio,
                "E^2*R" => RewardForm::EnergySquaredRatio,
                "E*R^2" => RewardForm::EnergyRatioSquared,
                other => return invalid(format!("unknown reward_form: {other}")),
            };
        }
        if let Some(v) = root.get_float("switch.latency_s") {
            // Must fit inside one decision interval: a stall >= dt_s would
            // make the switching step's useful time non-positive.
            if v < 0.0 || v >= cfg.dt_s {
                return invalid(format!(
                    "switch.latency_s must be in [0, dt_s = {})",
                    cfg.dt_s
                ));
            }
            cfg.switch_cost.latency_s = v;
        }
        if let Some(v) = root.get_float("switch.energy_j") {
            if v < 0.0 {
                return invalid("switch.energy_j must be >= 0");
            }
            cfg.switch_cost.energy_j = v;
        }
        if let Some(name) = root.get_str("policy.name") {
            cfg.policy = Self::parse_policy(name, root)?;
        }
        Ok(cfg)
    }

    fn parse_policy(name: &str, root: &Value) -> Result<PolicyConfig, ConfigError> {
        let ucb_cfg = |root: &Value| -> Result<EnergyUcbConfig, ConfigError> {
            let mut c = EnergyUcbConfig::default();
            if let Some(v) = root.get_float("policy.alpha") {
                if v < 0.0 {
                    return invalid("alpha must be >= 0");
                }
                c.alpha = v;
            }
            if let Some(v) = root.get_float("policy.lambda") {
                if v < 0.0 {
                    return invalid("lambda must be >= 0");
                }
                c.lambda = v;
            }
            if let Some(v) = root.get_float("policy.mu_init") {
                c.mu_init = v;
            }
            if let Some(v) = root.get_float("policy.prior_n") {
                c.prior_n = v;
            }
            if let Some(v) = root.get_float("policy.discount") {
                if v <= 0.0 || v > 1.0 {
                    return invalid("discount must be in (0, 1]");
                }
                c.discount = v;
            }
            if let Some(v) = root.get_str("policy.init") {
                c.init = match v {
                    "optimistic" => InitStrategy::Optimistic,
                    "warmup" => InitStrategy::WarmupRoundRobin,
                    other => return invalid(format!("unknown init: {other}")),
                };
            }
            Ok(c)
        };
        Ok(match name {
            "energyucb" => PolicyConfig::EnergyUcb(ucb_cfg(root)?),
            "constrained" => {
                let delta = root.get_float("policy.delta").unwrap_or(0.05);
                if !(0.0..1.0).contains(&delta) {
                    return invalid("delta must be in [0, 1)");
                }
                PolicyConfig::ConstrainedEnergyUcb { ucb: ucb_cfg(root)?, delta }
            }
            "ucb1" => PolicyConfig::Ucb1 { alpha: root.get_float("policy.alpha").unwrap_or(0.05) },
            "egreedy" => PolicyConfig::EpsilonGreedy {
                eps0: root.get_float("policy.eps0").unwrap_or(0.1),
                decay_c: root.get_float("policy.decay_c").unwrap_or(20.0),
            },
            "energyts" => PolicyConfig::EnergyTs,
            "rrfreq" => PolicyConfig::RoundRobin,
            "static" => {
                let arm = root.get_int("policy.arm").unwrap_or(8);
                if !(0..9).contains(&arm) {
                    return invalid("static arm must be in 0..9");
                }
                PolicyConfig::Static { arm: arm as usize }
            }
            "rlpower" => PolicyConfig::RlPower,
            "drlcap" => PolicyConfig::DrlCap {
                mode: root.get_str("policy.mode").unwrap_or("pretrain").to_string(),
            },
            other => return invalid(format!("unknown policy: {other}")),
        })
    }

    /// Instantiate the configured policy.
    pub fn build_policy(&self, k: usize, seed: u64) -> Box<dyn crate::bandit::Policy> {
        use crate::bandit::*;
        use crate::rl::{DrlCap, DrlCapMode, RlPower};
        match &self.policy {
            PolicyConfig::EnergyUcb(c) => Box::new(EnergyUcb::new(k, *c)),
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta } => {
                Box::new(ConstrainedEnergyUcb::new(k, *ucb, *delta))
            }
            PolicyConfig::Ucb1 { alpha } => Box::new(Ucb1::new(k, *alpha)),
            PolicyConfig::EpsilonGreedy { eps0, decay_c } => {
                Box::new(EpsilonGreedy::new(k, *eps0, *decay_c, seed))
            }
            PolicyConfig::EnergyTs => Box::new(EnergyTs::default_for(k, seed)),
            PolicyConfig::RoundRobin => Box::new(RoundRobin::new(k)),
            PolicyConfig::Static { arm } => Box::new(StaticPolicy::new(k, *arm)),
            PolicyConfig::RlPower => Box::new(RlPower::new(k, seed)),
            PolicyConfig::DrlCap { mode } => {
                let m = match mode.as_str() {
                    "online" => DrlCapMode::Online,
                    "cross" => DrlCapMode::CrossDeploy,
                    _ => DrlCapMode::PretrainDeploy,
                };
                Box::new(DrlCap::new(k, m, seed))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_empty() {
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.reps, 1);
        assert_eq!(c.apps, vec!["tealeaf".to_string()]);
        assert!(matches!(c.policy, PolicyConfig::EnergyUcb(_)));
    }

    #[test]
    fn full_config_parses() {
        let text = r#"
apps = ["llama", "sph_exa"]
reps = 10
seed = 42
reward_form = "E*R"

[policy]
name = "constrained"
alpha = 0.07
lambda = 0.02
delta = 0.05
"#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.apps.len(), 2);
        assert_eq!(c.reps, 10);
        match &c.policy {
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta } => {
                assert!((ucb.alpha - 0.07).abs() < 1e-12);
                assert!((ucb.lambda - 0.02).abs() < 1e-12);
                assert!((delta - 0.05).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_app() {
        assert!(ExperimentConfig::from_toml("apps = [\"nope\"]").is_err());
    }

    #[test]
    fn rejects_bad_hyperparams() {
        let bad = "
[policy]
name = \"energyucb\"
alpha = -1.0
";
        assert!(ExperimentConfig::from_toml(bad).is_err());
        assert!(ExperimentConfig::from_toml("dt_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[policy]\nname = \"bogus\"").is_err());
    }

    #[test]
    fn builds_each_policy_kind() {
        for name in
            ["energyucb", "constrained", "ucb1", "egreedy", "energyts", "rrfreq", "static", "rlpower", "drlcap"]
        {
            let text = format!("[policy]\nname = \"{name}\"");
            let c = ExperimentConfig::from_toml(&text).unwrap();
            let p = c.build_policy(9, 1);
            assert_eq!(p.k(), 9, "{name}");
        }
    }

    #[test]
    fn switch_cost_parses_and_validates() {
        let text = "[switch]\nlatency_s = 0.0003\nenergy_j = 1.2\n";
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert!((c.switch_cost.latency_s - 300e-6).abs() < 1e-12);
        assert!((c.switch_cost.energy_j - 1.2).abs() < 1e-12);
        // Defaults when absent.
        let c = ExperimentConfig::from_toml("").unwrap();
        assert_eq!(c.switch_cost, SwitchCost::default());
        assert!(ExperimentConfig::from_toml("[switch]\nenergy_j = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[switch]\nlatency_s = 2.0").is_err());
        // A stall >= the decision interval would run progress backwards.
        assert!(ExperimentConfig::from_toml("[switch]\nlatency_s = 0.01").is_err());
        // ... unless dt_s is raised accordingly.
        assert!(ExperimentConfig::from_toml("dt_s = 0.1\n[switch]\nlatency_s = 0.01").is_ok());
    }

    #[test]
    fn warmup_init_parses() {
        let text = "[policy]\nname = \"energyucb\"\ninit = \"warmup\"";
        let c = ExperimentConfig::from_toml(text).unwrap();
        match c.policy {
            PolicyConfig::EnergyUcb(u) => assert_eq!(u.init, InitStrategy::WarmupRoundRobin),
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod shipped_config_tests {
    use super::*;

    /// The checked-in configs under configs/ must always parse and build.
    #[test]
    fn shipped_configs_parse_and_build() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        let mut seen = 0;
        for entry in std::fs::read_dir(dir).expect("configs/ exists") {
            let path = entry.unwrap().path();
            if path.extension().and_then(|e| e.to_str()) != Some("toml") {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            let cfg = ExperimentConfig::from_toml(&text)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let policy = cfg.build_policy(9, 1);
            assert_eq!(policy.k(), 9, "{}", path.display());
            seen += 1;
        }
        assert!(seen >= 2, "expected shipped configs, found {seen}");
    }
}
