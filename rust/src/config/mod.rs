//! Configuration: TOML-subset parsing plus the typed experiment schema.

pub mod schema;
pub mod toml;

pub use schema::{ClusterFileConfig, ExperimentConfig, PolicyConfig};
pub use toml::{parse, Value};
