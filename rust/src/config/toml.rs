//! Minimal TOML-subset parser for run/experiment configuration files.
//!
//! Supported: `[table]` and `[dotted.table]` headers, `[[array.of.tables]]`
//! headers (each appends a table; later `[parent.child]` headers and dotted
//! keys descend into the *last* element, per TOML), `key = value` with
//! string / integer / float / boolean / homogeneous-array values, dotted
//! keys, `#` comments, and basic-string escapes. This covers everything the
//! launcher's config files use; exotic TOML (multi-line strings, dates,
//! inline tables) is intentionally rejected with a clear error rather than
//! mis-parsed.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`alpha = 1` is a valid float).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    /// Look up a dotted path like `"bandit.alpha"`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }

    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }
    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }
    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, message: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError { line, message: message.into() })
}

/// Parse a TOML-subset document into a root table.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut root = BTreeMap::new();
    // Path of the currently open [table].
    let mut current: Vec<String> = Vec::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = lineno + 1;
        let text = strip_comment(raw);
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        if let Some(rest) = text.strip_prefix('[') {
            if let Some(inner) = rest.strip_prefix('[') {
                // [[array.of.tables]] — append a fresh table to the array
                // at `path` and open it for subsequent keys.
                let Some(inner) = inner.strip_suffix("]]") else {
                    return err(line, "unterminated array-of-tables header");
                };
                let path = parse_key_path(inner, line)?;
                if path.is_empty() {
                    return err(line, "empty array-of-tables header");
                }
                push_array_table(&mut root, &path, line)?;
                current = path;
                continue;
            }
            let Some(inner) = rest.strip_suffix(']') else {
                return err(line, "unterminated table header");
            };
            let path = parse_key_path(inner, line)?;
            if path.is_empty() {
                return err(line, "empty table header");
            }
            // A plain [header] must not name an existing array of tables:
            // accepting it would silently reopen the last [[...]] element
            // (reject-don't-misparse, per the module contract).
            if terminal_is_array(&root, &path) {
                return err(
                    line,
                    format!("[{}] names an array of tables (use [[...]] to append)", inner.trim()),
                );
            }
            ensure_table(&mut root, &path, line)?;
            current = path;
            continue;
        }
        // key = value
        let Some(eq) = find_unquoted(text, '=') else {
            return err(line, format!("expected `key = value`, got: {text}"));
        };
        let key_part = text[..eq].trim();
        let val_part = text[eq + 1..].trim();
        if key_part.is_empty() {
            return err(line, "empty key");
        }
        if val_part.is_empty() {
            return err(line, "empty value");
        }
        let mut path = current.clone();
        path.extend(parse_key_path(key_part, line)?);
        let value = parse_value(val_part, line)?;
        insert(&mut root, &path, value, line)?;
    }
    Ok(Value::Table(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_unquoted(s: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == target && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_key_path(s: &str, line: usize) -> Result<Vec<String>, ParseError> {
    let mut out = Vec::new();
    for part in s.split('.') {
        let part = part.trim();
        let part = part.strip_prefix('"').and_then(|p| p.strip_suffix('"')).unwrap_or(part);
        if part.is_empty() {
            return err(line, "empty key segment");
        }
        if !part.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
            return err(line, format!("invalid key segment: {part:?}"));
        }
        out.push(part.to_string());
    }
    Ok(out)
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    line: usize,
) -> Result<&'a mut BTreeMap<String, Value>, ParseError> {
    let mut cur = root;
    for part in path {
        let entry = cur.entry(part.clone()).or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            // Descending through an array-of-tables targets its most
            // recently appended element (TOML's [[...]] semantics).
            Value::Array(a) => match a.last_mut() {
                Some(Value::Table(t)) => t,
                _ => return err(line, format!("key {part:?} is not a table")),
            },
            _ => return err(line, format!("key {part:?} is not a table")),
        };
    }
    Ok(cur)
}

/// Whether the entry at `path` (descending through array-of-tables last
/// elements along the prefix) is itself an array.
fn terminal_is_array(root: &BTreeMap<String, Value>, path: &[String]) -> bool {
    let (last, prefix) = path.split_last().expect("non-empty path");
    let mut cur = root;
    for part in prefix {
        cur = match cur.get(part) {
            Some(Value::Table(t)) => t,
            Some(Value::Array(a)) => match a.last() {
                Some(Value::Table(t)) => t,
                _ => return false,
            },
            _ => return false,
        };
    }
    matches!(cur.get(last), Some(Value::Array(_)))
}

/// Append an empty table to the array at `path` (creating the array if
/// absent), for a `[[path]]` header.
fn push_array_table(
    root: &mut BTreeMap<String, Value>,
    path: &[String],
    line: usize,
) -> Result<(), ParseError> {
    let (last, prefix) = path.split_last().expect("non-empty path");
    let parent = ensure_table(root, prefix, line)?;
    match parent.entry(last.clone()).or_insert_with(|| Value::Array(Vec::new())) {
        Value::Array(a) => {
            if a.iter().any(|v| !matches!(v, Value::Table(_))) {
                return err(line, format!("key {last:?} is not an array of tables"));
            }
            a.push(Value::Table(BTreeMap::new()));
            Ok(())
        }
        _ => err(line, format!("key {last:?} is not an array of tables")),
    }
}

fn insert(
    root: &mut BTreeMap<String, Value>,
    path: &[String],
    value: Value,
    line: usize,
) -> Result<(), ParseError> {
    let (last, prefix) = path.split_last().expect("non-empty path");
    let table = ensure_table(root, prefix, line)?;
    if table.contains_key(last) {
        return err(line, format!("duplicate key: {last:?}"));
    }
    table.insert(last.clone(), value);
    Ok(())
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s.starts_with('"') {
        return parse_string(s, line);
    }
    if s.starts_with('[') {
        return parse_array(s, line);
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    err(line, format!("cannot parse value: {s:?}"))
}

fn parse_string(s: &str, line: usize) -> Result<Value, ParseError> {
    let inner = &s[1..];
    let mut out = String::new();
    let mut chars = inner.chars();
    loop {
        match chars.next() {
            None => return err(line, "unterminated string"),
            Some('"') => break,
            Some('\\') => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return err(line, format!("bad escape: \\{other:?}")),
            },
            Some(c) => out.push(c),
        }
    }
    let rest: String = chars.collect();
    if !rest.trim().is_empty() {
        return err(line, format!("trailing characters after string: {rest:?}"));
    }
    Ok(Value::Str(out))
}

fn parse_array(s: &str, line: usize) -> Result<Value, ParseError> {
    let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) else {
        return err(line, "unterminated array");
    };
    let mut items = Vec::new();
    // Split on top-level commas (no nested arrays supported — reject).
    if inner.contains('[') {
        return err(line, "nested arrays are not supported");
    }
    for part in split_top_level(inner) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        items.push(parse_value(part, line)?);
    }
    // Homogeneity check (ints promote to float if mixed with floats).
    let any_float = items.iter().any(|v| matches!(v, Value::Float(_)));
    if any_float {
        for v in items.iter_mut() {
            if let Value::Int(i) = v {
                *v = Value::Float(*i as f64);
            }
        }
    }
    let homogeneous = items
        .windows(2)
        .all(|w| std::mem::discriminant(&w[0]) == std::mem::discriminant(&w[1]));
    if !homogeneous {
        return err(line, "heterogeneous arrays are not supported");
    }
    Ok(Value::Array(items))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
# experiment config
name = "table1"
reps = 10
alpha = 0.3
qos = false

[bandit]
lambda = 0.05
arms = [0.8, 0.9, 1.0]

[bandit.init]
mu = 0.0
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get_str("name"), Some("table1"));
        assert_eq!(v.get_int("reps"), Some(10));
        assert_eq!(v.get_float("alpha"), Some(0.3));
        assert_eq!(v.get_bool("qos"), Some(false));
        assert_eq!(v.get_float("bandit.lambda"), Some(0.05));
        assert_eq!(v.get_float("bandit.init.mu"), Some(0.0));
        let arms = v.get("bandit.arms").unwrap().as_array().unwrap();
        assert_eq!(arms.len(), 3);
        assert_eq!(arms[0].as_float(), Some(0.8));
    }

    #[test]
    fn int_promotes_to_float_in_mixed_array() {
        let v = parse("xs = [1, 2.5]").unwrap();
        let xs = v.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs[0].as_float(), Some(1.0));
    }

    #[test]
    fn dotted_keys() {
        let v = parse("a.b.c = 3").unwrap();
        assert_eq!(v.get_int("a.b.c"), Some(3));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(v.get_str("s"), Some("a\nb\"c"));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let v = parse("s = \"has # inside\" # trailing").unwrap();
        assert_eq!(v.get_str("s"), Some("has # inside"));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_value_reports_line() {
        let e = parse("\n\nx = @@@").unwrap_err();
        assert_eq!(e.line, 3);
    }

    #[test]
    fn array_of_tables_appends_elements() {
        let doc = r#"
[cluster]
nodes = 8

[[cluster.scenario]]
app = "tealeaf"
weight = 2.0

[cluster.scenario.policy]
name = "static"
arm = 4

[[cluster.scenario]]
app = "clvleaf"
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get_int("cluster.nodes"), Some(8));
        let scenarios = v.get("cluster.scenario").unwrap().as_array().unwrap();
        assert_eq!(scenarios.len(), 2);
        assert_eq!(scenarios[0].get_str("app"), Some("tealeaf"));
        assert_eq!(scenarios[0].get_float("weight"), Some(2.0));
        // A [parent.child] header after [[parent]] binds to the last element.
        assert_eq!(scenarios[0].get_str("policy.name"), Some("static"));
        assert_eq!(scenarios[0].get_int("policy.arm"), Some(4));
        assert_eq!(scenarios[1].get_str("app"), Some("clvleaf"));
        assert!(scenarios[1].get("policy").is_none());
    }

    #[test]
    fn array_of_tables_rejects_conflicts() {
        // Scalar key cannot become an array of tables.
        assert!(parse("servers = 1\n[[servers]]").is_err());
        // Inline (non-table) array cannot grow table elements.
        assert!(parse("servers = [1, 2]\n[[servers]]").is_err());
        assert!(parse("[[servers]").is_err());
        assert!(parse("[[]]").is_err());
    }

    #[test]
    fn plain_header_cannot_reopen_array_of_tables() {
        // [servers] after [[servers]] is a typo that would silently edit
        // the last element; reject it instead of mis-parsing.
        let doc = "[[servers]]\nname = \"a\"\n[servers]\nname = \"b\"";
        let e = parse(doc).unwrap_err();
        assert_eq!(e.line, 3);
        assert!(e.message.contains("[["), "{}", e.message);
        // Child headers of the last element remain fine.
        assert!(parse("[[servers]]\nname = \"a\"\n[servers.opts]\nx = 1").is_ok());
    }

    #[test]
    fn underscores_in_numbers() {
        let v = parse("n = 1_000_000").unwrap();
        assert_eq!(v.get_int("n"), Some(1_000_000));
    }

    #[test]
    fn float_from_int_lookup() {
        let v = parse("alpha = 1").unwrap();
        assert_eq!(v.get_float("alpha"), Some(1.0));
    }

    #[test]
    fn heterogeneous_array_rejected() {
        assert!(parse("xs = [1, \"a\"]").is_err());
    }

    #[test]
    fn empty_doc_is_empty_table() {
        let v = parse("  \n# nothing\n").unwrap();
        assert!(v.as_table().unwrap().is_empty());
    }
}
