//! Shard transports: how the leader executes one contiguous shard of the
//! assignment list.
//!
//! [`super::Leader::run_sharded`] is transport-agnostic — it partitions,
//! fans the shards out on leader threads, and merges whatever
//! [`WorkerEvent`] streams come back. Two backends:
//!
//! * [`InProcess`] — the shard runs on this process's work-stealing pool
//!   (`exec::run_indexed`); the default path, and the reference the
//!   subprocess path must match byte-for-byte.
//! * [`Subprocess`] — the shard is serialized over a framed-JSONL pipe to
//!   an `energyucb cluster-worker` child process (see [`super::wire`]),
//!   which runs it with the *same* in-process engine
//!   ([`run_shard_with`]) and streams events back on stdout. One
//!   subprocess per shard ≙ one controller host per node group — the
//!   process-isolation step toward multi-host fleets (a TCP backend
//!   slots in as a third `Transport` impl; see ROADMAP.md).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::mpsc;

use anyhow::Context;

use crate::exec::run_indexed;

use super::leader::{resolve_plans, ClusterConfig, NodeAssignment};
use super::wire::Frame;
use super::worker::{self, WorkerEvent};

/// A shard execution backend. `Sync` because the leader drives all
/// shards concurrently through a shared reference.
pub trait Transport: Sync {
    /// Backend name for status lines.
    fn name(&self) -> &'static str;

    /// Execute one contiguous shard and return every event it emitted:
    /// Progress beats interleaved, exactly one `Done` per assignment.
    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>>;
}

/// Deterministic contiguous partition: `shards` chunks whose sizes differ
/// by at most one, earlier chunks taking the remainder. Chunks that would
/// be empty (`shards > len`) are dropped, so every returned shard has
/// work.
pub fn partition(assignments: &[NodeAssignment], shards: usize) -> Vec<&[NodeAssignment]> {
    assert!(shards >= 1, "partition: shards must be >= 1");
    let len = assignments.len();
    let base = len / shards;
    let extra = len % shards;
    let mut parts = Vec::new();
    let mut start = 0;
    for s in 0..shards {
        let size = base + usize::from(s < extra);
        if size == 0 {
            continue;
        }
        parts.push(&assignments[start..start + size]);
        start += size;
    }
    parts
}

/// Run a shard on this process's work-stealing pool, handing every
/// drained event to `on_event` on a dedicated drainer thread (events
/// arrive one at a time, in channel order). `Leader::run`, the
/// [`InProcess`] backend, and the `cluster-worker` binary all execute
/// through this one path, so a subprocess shard is the same computation
/// as an in-process one.
pub(crate) fn run_shard_with<F>(
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
    mut on_event: F,
) -> anyhow::Result<()>
where
    F: FnMut(WorkerEvent) -> anyhow::Result<()> + Send,
{
    let plans = resolve_plans(cfg, shard)?;
    let (tx, rx) = mpsc::sync_channel::<WorkerEvent>(256);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        // If the sink fails (e.g. the leader end of a pipe is gone), the
        // drainer drops `rx`; worker sends then error and the nodes
        // finish without streaming — the pool always drains.
        let drainer = scope.spawn(move || -> anyhow::Result<()> {
            for ev in rx {
                on_event(ev)?;
            }
            Ok(())
        });
        {
            let tx = &tx;
            run_indexed(cfg.jobs, plans.len(), |i| {
                let p = &plans[i];
                // Policy arity follows the plan's own frequency domain
                // (per-node domains are expressible).
                let policy = p.policy.build(p.session.freqs.k(), p.session.seed);
                worker::run_node(p.node, &p.app, policy, &p.session, cfg.heartbeat_steps, tx);
            });
        }
        drop(tx);
        drainer.join().map_err(|_| anyhow::anyhow!("event drainer panicked"))?
    })
}

/// Run shards on this process's pool (no serialization involved).
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl Transport for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>> {
        let mut events = Vec::new();
        run_shard_with(cfg, shard, |ev| {
            events.push(ev);
            Ok(())
        })?;
        Ok(events)
    }
}

/// Serialize each shard to an `energyucb cluster-worker` child process
/// over framed JSONL: `config` + `assign`* + `run` down its stdin,
/// `event`* + `end` back from its stdout (stderr passes through for
/// timing chatter). The worker receives assignments *only* through this
/// wire — there is no shared memory with the leader.
#[derive(Clone, Debug)]
pub struct Subprocess {
    program: PathBuf,
}

impl Subprocess {
    /// Workers spawn from the currently running executable — the normal
    /// CLI path, where leader and worker are the same binary.
    pub fn current_exe() -> anyhow::Result<Subprocess> {
        let program = std::env::current_exe().context("resolving current executable")?;
        Ok(Subprocess { program })
    }

    /// Workers spawn from an explicit binary (tests pass the cargo-built
    /// CLI via `env!("CARGO_BIN_EXE_energyucb")` — `current_exe()` inside
    /// a test harness would re-enter the *test* binary).
    pub fn with_program(program: impl Into<PathBuf>) -> Subprocess {
        Subprocess { program: program.into() }
    }
}

impl Transport for Subprocess {
    fn name(&self) -> &'static str {
        "subprocess"
    }

    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>> {
        let mut child = Command::new(&self.program)
            .arg("cluster-worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning cluster-worker from {}", self.program.display()))?;
        let outcome = drive_worker(&mut child, cfg, shard);
        if outcome.is_err() {
            // Reap on every failure path: a bailed-on worker would
            // otherwise keep simulating its whole shard in the
            // background, then linger as a zombie until leader exit.
            let _ = child.kill();
            let _ = child.wait();
            return outcome;
        }
        let status = child.wait().context("waiting for cluster-worker")?;
        if !status.success() {
            anyhow::bail!("cluster-worker exited with {status}");
        }
        outcome
    }
}

/// The leader half of one worker conversation: feed the batch, then
/// collect the event stream and check its terminal frame. On any error
/// the caller kills and reaps the child.
fn drive_worker(
    child: &mut std::process::Child,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
) -> anyhow::Result<Vec<WorkerEvent>> {
    if let Err(feed_err) = feed_worker(child, cfg, shard) {
        // A worker that rejects an early frame writes an `error` frame and
        // exits while the leader may still be mid-batch — the resulting
        // broken-pipe write error would mask the real reason. Drain stdout
        // (the worker is gone or about to be: closing stdin above ends its
        // read loop) and surface the worker's own message when present.
        if let Some(out) = child.stdout.take() {
            for line in BufReader::new(out).lines().map_while(Result::ok) {
                if let Ok(Frame::Error { message }) = Frame::decode_line(&line) {
                    return Err(feed_err.context(format!(
                        "cluster-worker rejected the shard batch: {message}"
                    )));
                }
            }
        }
        return Err(feed_err);
    }

    let reader = BufReader::new(child.stdout.take().expect("piped stdout"));
    let mut events = Vec::new();
    let mut end_nodes: Option<usize> = None;
    for line in reader.lines() {
        let line = line.context("reading cluster-worker stdout")?;
        if line.trim().is_empty() {
            continue;
        }
        match Frame::decode_line(&line)
            .with_context(|| format!("bad frame from cluster-worker: {line}"))?
        {
            Frame::Event(ev) => events.push(ev),
            Frame::End { nodes } => end_nodes = Some(nodes),
            Frame::Error { message } => {
                anyhow::bail!("cluster-worker shard failed: {message}");
            }
            other => anyhow::bail!("unexpected frame from cluster-worker: {other:?}"),
        }
    }
    match end_nodes {
        Some(n) if n == shard.len() => Ok(events),
        Some(n) => {
            anyhow::bail!("shard integrity: worker reported {n} nodes, expected {}", shard.len())
        }
        None => anyhow::bail!("cluster-worker stream ended without a terminal frame"),
    }
}

/// Feed the whole batch, then close stdin (the `BufWriter` and pipe drop
/// on return — including the error path, which is what lets the caller
/// then read the worker's stream to EOF). No deadlock window: the worker
/// writes nothing before it has consumed up to `run`.
fn feed_worker(
    child: &mut std::process::Child,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
) -> anyhow::Result<()> {
    let stdin = child.stdin.take().expect("piped stdin");
    let mut w = BufWriter::new(stdin);
    let config = Frame::Config {
        jobs: cfg.jobs,
        heartbeat_steps: cfg.heartbeat_steps,
        policy: cfg.policy.clone(),
        session: cfg.session.clone(),
    };
    writeln!(w, "{}", config.encode_line()).context("writing config frame")?;
    for a in shard {
        writeln!(w, "{}", Frame::Assign(a.clone()).encode_line())
            .context("writing assignment frame")?;
    }
    writeln!(w, "{}", Frame::Run.encode_line()).context("writing run frame")?;
    w.flush().context("flushing worker stdin")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Leader;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        let a: Vec<NodeAssignment> =
            (0..10).map(|n| NodeAssignment::new(n, "tealeaf", n as u64)).collect();
        for shards in 1..=12 {
            let parts = partition(&a, shards);
            assert_eq!(parts.len(), shards.min(10), "shards={shards}");
            // Re-concatenation reproduces the input order exactly.
            let glued: Vec<usize> = parts.iter().flat_map(|p| p.iter().map(|x| x.node)).collect();
            assert_eq!(glued, (0..10).collect::<Vec<_>>(), "shards={shards}");
            // Balanced: sizes differ by at most one.
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "shards={shards}: {sizes:?}");
        }
    }

    #[test]
    fn in_process_shard_emits_one_done_per_assignment() {
        let cfg = ClusterConfig {
            jobs: 2,
            heartbeat_steps: 100,
            session: crate::control::SessionCfg {
                max_steps: 300,
                ..crate::control::SessionCfg::default()
            },
            ..ClusterConfig::default()
        };
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 4, 11);
        let events = InProcess.run_shard(&cfg, &assignments).unwrap();
        let done: Vec<usize> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Done { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        let mut sorted = done.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        // 300 steps / 100-step beats = 3 Progress events per node.
        let beats = events
            .iter()
            .filter(|e| matches!(e, WorkerEvent::Progress { .. }))
            .count();
        assert_eq!(beats, 4 * 3);
    }

    #[test]
    fn missing_worker_binary_is_a_clean_error() {
        let cfg = ClusterConfig { jobs: 1, ..ClusterConfig::default() };
        let assignments = Leader::assign_round_robin(&["tealeaf"], 1, 0);
        let t = Subprocess::with_program("/nonexistent/energyucb-cluster-worker");
        let e = t.run_shard(&cfg, &assignments).unwrap_err();
        assert!(format!("{e:#}").contains("spawning cluster-worker"), "{e:#}");
    }
}
