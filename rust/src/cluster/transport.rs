//! Shard transports: how the leader executes one contiguous shard of the
//! assignment list.
//!
//! [`super::Leader::run_sharded`] is transport-agnostic — it partitions,
//! fans the shards out on leader threads, and merges whatever
//! [`WorkerEvent`] streams come back. Two backends:
//!
//! * [`InProcess`] — the shard runs on this process's work-stealing pool
//!   (`exec::run_indexed`); the default path, and the reference the
//!   subprocess path must match byte-for-byte.
//! * [`Subprocess`] — the shard is serialized over a framed-JSONL pipe to
//!   an `energyucb cluster-worker` child process (see [`super::wire`]),
//!   which runs it with the *same* in-process engine
//!   ([`run_shard_with`]) and streams events back on stdout. One
//!   subprocess per shard ≙ one controller host per node group — the
//!   process-isolation step toward multi-host fleets.
//! * [`Tcp`] — the multi-host backend: the leader listens, remote
//!   `energyucb cluster-worker --connect HOST:PORT` processes dial in,
//!   and each shard is one `config`/`assign`*/`run` batch down a
//!   connection with the `event`*/`end` stream coming back — the exact
//!   frame grammar of the pipe transport, over a socket. Connections are
//!   pooled and reused across batches; a connection whose worker dies or
//!   stalls (read deadline) is dropped, and the leader's requeue logic
//!   re-runs the shard on survivors.
//!
//! Every read path carries a deadline: a hung or killed worker surfaces
//! as an error within `timeout`, never as a leader that blocks forever.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::exec::run_indexed;

use super::leader::{resolve_plans, ClusterConfig, NodeAssignment};
use super::wire::Frame;
use super::worker::{self, WorkerEvent};

/// Default per-shard read deadline: how long the leader waits for the
/// *next* frame from a worker before declaring it dead. Heartbeats arrive
/// every `heartbeat_steps` decisions, so any live shard beats far inside
/// this window.
pub const DEFAULT_SHARD_TIMEOUT: Duration = Duration::from_secs(120);

/// A shard execution backend. `Sync` because the leader drives all
/// shards concurrently through a shared reference.
pub trait Transport: Sync {
    /// Backend name for status lines.
    fn name(&self) -> &'static str;

    /// Execute one contiguous shard and return every event it emitted:
    /// Progress beats interleaved, exactly one `Done` per assignment.
    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>>;

    /// How many shards this backend can still serve concurrently, if the
    /// backend tracks membership (`None` = effectively unbounded —
    /// process-local backends mint workers on demand). The leader's
    /// requeue path consults this so it stops re-offering work once every
    /// remote worker is gone.
    fn capacity(&self) -> Option<usize> {
        None
    }
}

/// Deterministic contiguous partition: `shards` chunks whose sizes differ
/// by at most one, earlier chunks taking the remainder. Chunks that would
/// be empty (`shards > len`) are dropped, so every returned shard has
/// work.
pub fn partition(assignments: &[NodeAssignment], shards: usize) -> Vec<&[NodeAssignment]> {
    assert!(shards >= 1, "partition: shards must be >= 1");
    let len = assignments.len();
    let base = len / shards;
    let extra = len % shards;
    let mut parts = Vec::new();
    let mut start = 0;
    for s in 0..shards {
        let size = base + usize::from(s < extra);
        if size == 0 {
            continue;
        }
        parts.push(&assignments[start..start + size]);
        start += size;
    }
    parts
}

/// Run a shard on this process's work-stealing pool, handing every
/// drained event to `on_event` on a dedicated drainer thread (events
/// arrive one at a time, in channel order). `Leader::run`, the
/// [`InProcess`] backend, and the `cluster-worker` binary all execute
/// through this one path, so a subprocess shard is the same computation
/// as an in-process one.
pub(crate) fn run_shard_with<F>(
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
    mut on_event: F,
) -> anyhow::Result<()>
where
    F: FnMut(WorkerEvent) -> anyhow::Result<()> + Send,
{
    let plans = resolve_plans(cfg, shard)?;
    let (tx, rx) = mpsc::sync_channel::<WorkerEvent>(256);
    std::thread::scope(|scope| -> anyhow::Result<()> {
        // If the sink fails (e.g. the leader end of a pipe is gone), the
        // drainer drops `rx`; worker sends then error and the nodes
        // finish without streaming — the pool always drains.
        let drainer = scope.spawn(move || -> anyhow::Result<()> {
            for ev in rx {
                on_event(ev)?;
            }
            Ok(())
        });
        {
            let tx = &tx;
            run_indexed(cfg.jobs, plans.len(), |i| {
                let p = &plans[i];
                // Policy arity follows the plan's own frequency domain
                // (per-node domains are expressible).
                let policy = p.policy.build(p.session.freqs.k(), p.session.seed);
                worker::run_node(p.node, &p.app, policy, &p.session, cfg.heartbeat_steps, tx);
            });
        }
        drop(tx);
        drainer.join().map_err(|_| anyhow::anyhow!("event drainer panicked"))?
    })
}

/// Run shards on this process's pool (no serialization involved).
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcess;

impl Transport for InProcess {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>> {
        let mut events = Vec::new();
        run_shard_with(cfg, shard, |ev| {
            events.push(ev);
            Ok(())
        })?;
        Ok(events)
    }
}

/// Serialize each shard to an `energyucb cluster-worker` child process
/// over framed JSONL: `config` + `assign`* + `run` down its stdin,
/// `event`* + `end` back from its stdout (stderr passes through for
/// timing chatter). The worker receives assignments *only* through this
/// wire — there is no shared memory with the leader.
#[derive(Clone, Debug)]
pub struct Subprocess {
    program: PathBuf,
    /// Per-frame read deadline (see [`DEFAULT_SHARD_TIMEOUT`]).
    timeout: Duration,
    /// Extra `cluster-worker` argv (test hook: fault injection flags like
    /// `--die-after-events N` ride here).
    worker_args: Vec<String>,
}

impl Subprocess {
    /// Workers spawn from the currently running executable — the normal
    /// CLI path, where leader and worker are the same binary.
    pub fn current_exe() -> anyhow::Result<Subprocess> {
        let program = std::env::current_exe().context("resolving current executable")?;
        Ok(Subprocess { program, timeout: DEFAULT_SHARD_TIMEOUT, worker_args: Vec::new() })
    }

    /// Workers spawn from an explicit binary (tests pass the cargo-built
    /// CLI via `env!("CARGO_BIN_EXE_energyucb")` — `current_exe()` inside
    /// a test harness would re-enter the *test* binary).
    pub fn with_program(program: impl Into<PathBuf>) -> Subprocess {
        Subprocess {
            program: program.into(),
            timeout: DEFAULT_SHARD_TIMEOUT,
            worker_args: Vec::new(),
        }
    }

    /// Override the per-frame read deadline.
    pub fn with_timeout(mut self, timeout: Duration) -> Subprocess {
        self.timeout = timeout;
        self
    }

    /// Append extra argv to every spawned `cluster-worker` (fault
    /// injection in tests).
    pub fn with_worker_args<I, S>(mut self, args: I) -> Subprocess
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.worker_args = args.into_iter().map(Into::into).collect();
        self
    }
}

impl Transport for Subprocess {
    fn name(&self) -> &'static str {
        "subprocess"
    }

    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>> {
        let mut child = Command::new(&self.program)
            .arg("cluster-worker")
            .args(&self.worker_args)
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning cluster-worker from {}", self.program.display()))?;
        let outcome = drive_worker(&mut child, cfg, shard, self.timeout);
        if outcome.is_err() {
            // Reap on every failure path: a bailed-on worker would
            // otherwise keep simulating its whole shard in the
            // background, then linger as a zombie until leader exit.
            let _ = child.kill();
            let _ = child.wait();
            return outcome;
        }
        let status = child.wait().context("waiting for cluster-worker")?;
        if !status.success() {
            anyhow::bail!("cluster-worker exited with {status}");
        }
        outcome
    }
}

/// The leader half of one worker conversation: feed the batch, then
/// collect the event stream and check its terminal frame. Every read
/// carries the `timeout` deadline — a worker that stops emitting frames
/// (hung, SIGSTOPped, wedged) is declared dead instead of blocking the
/// leader forever. On any error the caller kills and reaps the child.
fn drive_worker(
    child: &mut std::process::Child,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
    timeout: Duration,
) -> anyhow::Result<Vec<WorkerEvent>> {
    if let Err(feed_err) = feed_worker(child, cfg, shard) {
        // A worker that rejects an early frame writes an `error` frame and
        // exits while the leader may still be mid-batch — the resulting
        // broken-pipe write error would mask the real reason. Drain stdout
        // (the worker is gone or about to be: closing stdin above ends its
        // read loop) and surface the worker's own message when present.
        if let Some(out) = child.stdout.take() {
            for line in BufReader::new(out).lines().map_while(Result::ok) {
                if let Ok(Frame::Error { message }) = Frame::decode_line(&line) {
                    return Err(feed_err.context(format!(
                        "cluster-worker rejected the shard batch: {message}"
                    )));
                }
            }
        }
        return Err(feed_err);
    }

    // Pipe reads cannot time out directly, so a detached reader thread
    // pumps lines into a channel and the deadline lives on `recv_timeout`.
    // On timeout the caller kills the child, which EOFs the pipe and lets
    // the reader thread exit; the dropped receiver unblocks any pending
    // send the same way.
    let out = child.stdout.take().expect("piped stdout");
    let (ltx, lrx) = mpsc::sync_channel::<std::io::Result<String>>(256);
    std::thread::spawn(move || {
        for line in BufReader::new(out).lines() {
            if ltx.send(line).is_err() {
                return; // leader gave up on this worker
            }
        }
    });
    let mut events = Vec::new();
    let mut end_nodes: Option<usize> = None;
    loop {
        let line = match lrx.recv_timeout(timeout) {
            Ok(Ok(line)) => line,
            Ok(Err(e)) => return Err(e).context("reading cluster-worker stdout"),
            Err(mpsc::RecvTimeoutError::Timeout) => anyhow::bail!(
                "cluster-worker emitted no frame within {timeout:?} (hung or stalled worker)"
            ),
            Err(mpsc::RecvTimeoutError::Disconnected) => break, // EOF
        };
        if line.trim().is_empty() {
            continue;
        }
        match Frame::decode_line(&line)
            .with_context(|| format!("bad frame from cluster-worker: {line}"))?
        {
            Frame::Event(ev) => events.push(ev),
            Frame::End { nodes } => end_nodes = Some(nodes),
            Frame::Error { message } => {
                anyhow::bail!("cluster-worker shard failed: {message}");
            }
            other => anyhow::bail!("unexpected frame from cluster-worker: {other:?}"),
        }
    }
    match end_nodes {
        Some(n) if n == shard.len() => Ok(events),
        Some(n) => {
            anyhow::bail!("shard integrity: worker reported {n} nodes, expected {}", shard.len())
        }
        None => anyhow::bail!("cluster-worker stream ended without a terminal frame"),
    }
}

/// Write one shard batch — `config`, `assign`*, `run` — and flush. The
/// single writer both the pipe and the socket transports use, so the
/// on-wire bytes are identical per shard regardless of carrier.
fn write_batch<W: Write>(
    w: &mut W,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
) -> anyhow::Result<()> {
    let config = Frame::Config {
        jobs: cfg.jobs,
        heartbeat_steps: cfg.heartbeat_steps,
        policy: cfg.policy.clone(),
        session: cfg.session.clone(),
    };
    writeln!(w, "{}", config.encode_line()).context("writing config frame")?;
    for a in shard {
        writeln!(w, "{}", Frame::Assign(a.clone()).encode_line())
            .context("writing assignment frame")?;
    }
    writeln!(w, "{}", Frame::Run.encode_line()).context("writing run frame")?;
    w.flush().context("flushing shard batch")?;
    Ok(())
}

/// Feed the whole batch, then close stdin (the `BufWriter` and pipe drop
/// on return — including the error path, which is what lets the caller
/// then read the worker's stream to EOF). No deadlock window: the worker
/// writes nothing before it has consumed up to `run`.
fn feed_worker(
    child: &mut std::process::Child,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
) -> anyhow::Result<()> {
    let stdin = child.stdin.take().expect("piped stdin");
    write_batch(&mut BufWriter::new(stdin), cfg, shard)
}

/// The multi-host transport: the leader listens, remote `energyucb
/// cluster-worker --connect HOST:PORT` processes dial in, and each shard
/// rides one connection as a `config`/`assign`*/`run` batch with the
/// `event`*/`end` stream coming back — the pipe transport's frame grammar
/// verbatim, over a socket.
///
/// Membership is implicit: a connection *is* a ready worker. Connections
/// are pooled in [`Tcp::run_shard`]'s success path and reused for later
/// batches (one worker can serve many shards); a connection whose worker
/// errors, dies (EOF mid-batch), or stalls past the read deadline is
/// dropped and never reused — the leader's requeue logic re-runs the
/// shard on survivors, and [`Transport::capacity`] reports how many
/// remain.
pub struct Tcp {
    listener: TcpListener,
    /// Connected workers with no batch in flight.
    idle: Mutex<VecDeque<TcpStream>>,
    timeout: Duration,
}

impl Tcp {
    /// Bind the leader-side listener. `addr` is a `HOST:PORT` bind
    /// address (`127.0.0.1:0` for an ephemeral test port — read it back
    /// with [`local_addr`](Self::local_addr)). `timeout` bounds every
    /// wait: accepting a worker for a shard, and each frame read.
    pub fn listen(addr: &str, timeout: Duration) -> anyhow::Result<Tcp> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding cluster TCP listener on {addr}"))?;
        // Nonblocking so accept polls can carry a deadline; per-connection
        // read timeouts are set when a shard is driven.
        listener.set_nonblocking(true).context("setting listener nonblocking")?;
        Ok(Tcp { listener, idle: Mutex::new(VecDeque::new()), timeout })
    }

    /// The bound address (workers dial this).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        self.listener.local_addr().context("resolving cluster TCP listener address")
    }

    /// Sweep any workers that connected since the last look into the idle
    /// pool (accept never blocks — the listener is nonblocking).
    fn drain_pending_accepts(&self) {
        let mut idle = self.idle.lock().unwrap();
        while let Ok((stream, _peer)) = self.listener.accept() {
            let _ = stream.set_nodelay(true); // frames are small and latency-bound
            idle.push_back(stream);
        }
    }

    /// A connection to run one shard on: a pooled idle worker if any,
    /// else poll-accept until one dials in or the deadline passes.
    fn take_conn(&self) -> anyhow::Result<TcpStream> {
        let deadline = Instant::now() + self.timeout;
        loop {
            self.drain_pending_accepts();
            if let Some(conn) = self.idle.lock().unwrap().pop_front() {
                return Ok(conn);
            }
            if Instant::now() >= deadline {
                anyhow::bail!(
                    "no cluster-worker connected within {:?} (start workers with \
                     `energyucb cluster-worker --connect HOST:PORT`)",
                    self.timeout
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn capacity(&self) -> Option<usize> {
        self.drain_pending_accepts();
        Some(self.idle.lock().unwrap().len())
    }

    fn run_shard(
        &self,
        cfg: &ClusterConfig,
        shard: &[NodeAssignment],
    ) -> anyhow::Result<Vec<WorkerEvent>> {
        let conn = self.take_conn()?;
        match drive_tcp_worker(&conn, cfg, shard, self.timeout) {
            Ok(events) => {
                // Healthy conversation: the worker is ready for another
                // batch — return it to the pool.
                self.idle.lock().unwrap().push_back(conn);
                Ok(events)
            }
            // Any failure drops `conn` (closing the socket): a worker that
            // errored, died, or stalled is never trusted with more work.
            Err(e) => Err(e),
        }
    }
}

/// One shard conversation over an established worker connection: write
/// the batch, then read `event`* up to the in-stream terminal (`end` or
/// `error`). Unlike the pipe transport, EOF is *not* a clean terminal —
/// the connection outlives the batch, so a closed socket mid-batch means
/// the worker died. Every read carries the deadline via
/// `set_read_timeout`.
fn drive_tcp_worker(
    conn: &TcpStream,
    cfg: &ClusterConfig,
    shard: &[NodeAssignment],
    timeout: Duration,
) -> anyhow::Result<Vec<WorkerEvent>> {
    conn.set_read_timeout(Some(timeout)).context("setting socket read deadline")?;
    let mut writer = BufWriter::new(conn.try_clone().context("cloning worker socket")?);
    write_batch(&mut writer, cfg, shard)?;
    drop(writer);
    let mut reader = BufReader::new(conn.try_clone().context("cloning worker socket")?);
    let mut events = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                anyhow::bail!(
                    "cluster-worker stream ended without a terminal frame \
                     (worker connection closed mid-batch)"
                );
            }
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                anyhow::bail!(
                    "cluster-worker sent no frame within {timeout:?} (hung or stalled worker)"
                );
            }
            Err(e) => return Err(e).context("reading cluster-worker socket"),
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match Frame::decode_line(trimmed)
            .with_context(|| format!("bad frame from cluster-worker: {trimmed}"))?
        {
            Frame::Event(ev) => events.push(ev),
            Frame::End { nodes } if nodes == shard.len() => return Ok(events),
            Frame::End { nodes } => anyhow::bail!(
                "shard integrity: worker reported {nodes} nodes, expected {}",
                shard.len()
            ),
            Frame::Error { message } => {
                anyhow::bail!("cluster-worker shard failed: {message}");
            }
            other => anyhow::bail!("unexpected frame from cluster-worker: {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Leader;

    #[test]
    fn partition_is_contiguous_and_balanced() {
        let a: Vec<NodeAssignment> =
            (0..10).map(|n| NodeAssignment::new(n, "tealeaf", n as u64)).collect();
        for shards in 1..=12 {
            let parts = partition(&a, shards);
            assert_eq!(parts.len(), shards.min(10), "shards={shards}");
            // Re-concatenation reproduces the input order exactly.
            let glued: Vec<usize> = parts.iter().flat_map(|p| p.iter().map(|x| x.node)).collect();
            assert_eq!(glued, (0..10).collect::<Vec<_>>(), "shards={shards}");
            // Balanced: sizes differ by at most one.
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "shards={shards}: {sizes:?}");
        }
    }

    #[test]
    fn in_process_shard_emits_one_done_per_assignment() {
        let cfg = ClusterConfig {
            jobs: 2,
            heartbeat_steps: 100,
            session: crate::control::SessionCfg {
                max_steps: 300,
                ..crate::control::SessionCfg::default()
            },
            ..ClusterConfig::default()
        };
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 4, 11);
        let events = InProcess.run_shard(&cfg, &assignments).unwrap();
        let done: Vec<usize> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Done { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        let mut sorted = done.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        // 300 steps / 100-step beats = 3 Progress events per node.
        let beats = events
            .iter()
            .filter(|e| matches!(e, WorkerEvent::Progress { .. }))
            .count();
        assert_eq!(beats, 4 * 3);
    }

    #[test]
    fn missing_worker_binary_is_a_clean_error() {
        let cfg = ClusterConfig { jobs: 1, ..ClusterConfig::default() };
        let assignments = Leader::assign_round_robin(&["tealeaf"], 1, 0);
        let t = Subprocess::with_program("/nonexistent/energyucb-cluster-worker");
        let e = t.run_shard(&cfg, &assignments).unwrap_err();
        assert!(format!("{e:#}").contains("spawning cluster-worker"), "{e:#}");
    }

    #[test]
    fn tcp_with_no_workers_times_out_cleanly() {
        let t = Tcp::listen("127.0.0.1:0", Duration::from_millis(200)).unwrap();
        assert_eq!(t.capacity(), Some(0));
        let cfg = ClusterConfig { jobs: 1, ..ClusterConfig::default() };
        let assignments = Leader::assign_round_robin(&["tealeaf"], 1, 0);
        let start = Instant::now();
        let e = t.run_shard(&cfg, &assignments).unwrap_err();
        assert!(format!("{e:#}").contains("no cluster-worker connected"), "{e:#}");
        // Bounded by the accept deadline, not a hang.
        assert!(start.elapsed() < Duration::from_secs(30));
    }

    #[test]
    fn tcp_hung_worker_hits_the_read_deadline() {
        let t = Tcp::listen("127.0.0.1:0", Duration::from_millis(300)).unwrap();
        let addr = t.local_addr().unwrap();
        // A "worker" that connects but never speaks: the shard must fail
        // on the frame deadline, and the dead connection must not be
        // returned to the pool.
        let _fake = TcpStream::connect(addr).unwrap();
        let cfg = ClusterConfig { jobs: 1, ..ClusterConfig::default() };
        let assignments = Leader::assign_round_robin(&["tealeaf"], 1, 0);
        let start = Instant::now();
        let e = t.run_shard(&cfg, &assignments).unwrap_err();
        assert!(format!("{e:#}").contains("no frame within"), "{e:#}");
        assert!(start.elapsed() < Duration::from_secs(30));
        assert_eq!(t.capacity(), Some(0), "failed connection must be dropped, not pooled");
    }
}
