//! Scenario schedules: deterministic generators of cluster assignment
//! mixes beyond round-robin — weighted app mixes, phased/staggered
//! arrivals (per-node step budgets), per-app policy overrides, and
//! heterogeneous nodes (per-node switch cost drawn from a configured set).
//!
//! Generation is a pure function of `(seed, node)`: every per-node draw
//! comes from `exec::cell_rng(seed, node)`, so the assignment list is
//! independent of worker count and iteration order — the same
//! order-independence contract the experiment executor uses, extended to
//! the fleet layer (see EXPERIMENTS.md §Cluster).

use crate::config::PolicyConfig;
use crate::exec::cell_rng;
use crate::sim::freq::SwitchCost;
use crate::workload::calibration;

use super::leader::NodeAssignment;

/// One entry of the app mix: a workload, its share of the fleet, and an
/// optional policy override for nodes running it.
#[derive(Clone, Debug, PartialEq)]
pub struct AppSlot {
    pub app: String,
    pub weight: f64,
    pub policy: Option<PolicyConfig>,
}

impl AppSlot {
    pub fn new(app: &str) -> AppSlot {
        AppSlot { app: app.to_string(), weight: 1.0, policy: None }
    }
}

/// How nodes are mapped onto the app mix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pick {
    /// Cycle through the slots in order (weights ignored).
    RoundRobin,
    /// Draw each node's slot proportionally to the weights.
    Weighted,
}

/// Arrival pattern: how much work each node has when the run starts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrivals {
    /// Every node runs its app to completion.
    Uniform,
    /// Nodes arrive in `phases` staggered groups: phase `p = node % phases`
    /// gets a step budget of `base_steps` scaled linearly from `min_frac`
    /// (phase 0) up to 1.0 (the last phase) — a mixed-duration fleet where
    /// fixed waves idle behind their longest member.
    Staggered { phases: usize, min_frac: f64, base_steps: u64 },
}

/// A deterministic generator of [`NodeAssignment`] lists.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSchedule {
    /// Display name ("mixed", "staggered", ...).
    pub name: String,
    pub slots: Vec<AppSlot>,
    pub pick: Pick,
    pub arrivals: Arrivals,
    /// Per-node switch-cost choices; empty = homogeneous fleet (the
    /// cluster session default applies).
    pub switch_costs: Vec<SwitchCost>,
    /// Base seed: node `n` gets session seed `seed + n` and draw stream
    /// `cell_rng(seed, n)`.
    pub seed: u64,
}

/// The short/medium calibrated apps used by the named presets (the long
/// LLM/diffusion runs are covered by `energyucb exp impact`).
pub const PRESET_APPS: [&str; 6] = ["lbm", "tealeaf", "clvleaf", "miniswp", "pot3d", "weather"];

impl ScenarioSchedule {
    /// Plain round-robin of `apps`, uniform arrivals, homogeneous nodes —
    /// the schedule the pre-scenario cluster ran (same `seed + n` session
    /// seeds, so reports cross-check against the wave era).
    pub fn round_robin(apps: &[&str], seed: u64) -> ScenarioSchedule {
        ScenarioSchedule {
            name: "round_robin".into(),
            slots: apps.iter().map(|a| AppSlot::new(a)).collect(),
            pick: Pick::RoundRobin,
            arrivals: Arrivals::Uniform,
            switch_costs: Vec::new(),
            seed,
        }
    }

    /// Named presets behind `energyucb cluster --scenario <name>`.
    ///
    /// * `uniform` — round-robin over the preset apps, equal-length runs;
    /// * `mixed` — weighted app mix with a per-app policy override
    ///   (compute-bound lbm pinned at its known-best static frequency);
    /// * `staggered` — 4 arrival phases with 25–100 % step budgets;
    /// * `hetero` — per-node switch cost drawn from 1×/3×/6× the paper's
    ///   measured transition cost;
    /// * `chaos` — the kill-under-load scenario: the mixed weighted app
    ///   set under 3-phase staggered arrivals (short, bounded runs) on
    ///   heterogeneous switch costs. The schedule itself is ordinary —
    ///   the chaos comes from the harness killing workers mid-run
    ///   (`energyucb cluster --chaos-kill`) while the report must stay
    ///   byte-identical to a failure-free run.
    pub fn preset(name: &str, seed: u64) -> Option<ScenarioSchedule> {
        let mut s = ScenarioSchedule::round_robin(&PRESET_APPS, seed);
        s.name = name.to_string();
        match name {
            "uniform" => {}
            "mixed" => {
                s.pick = Pick::Weighted;
                s.slots = vec![
                    AppSlot { weight: 3.0, ..AppSlot::new("tealeaf") },
                    AppSlot { weight: 2.0, ..AppSlot::new("clvleaf") },
                    AppSlot {
                        weight: 1.0,
                        policy: Some(PolicyConfig::Static { arm: 7 }),
                        ..AppSlot::new("lbm")
                    },
                    AppSlot { weight: 1.0, ..AppSlot::new("miniswp") },
                    AppSlot { weight: 1.0, ..AppSlot::new("weather") },
                ];
            }
            "staggered" => {
                s.arrivals = Arrivals::Staggered { phases: 4, min_frac: 0.25, base_steps: 6_000 };
            }
            "hetero" => {
                let base = SwitchCost::default();
                s.switch_costs = (0..3)
                    .map(|i| {
                        let m = (1 << i) as f64 + i as f64; // 1x, 3x, 6x
                        SwitchCost { latency_s: base.latency_s * m, energy_j: base.energy_j * m }
                    })
                    .collect();
            }
            "chaos" => {
                s.pick = Pick::Weighted;
                s.slots = vec![
                    AppSlot { weight: 3.0, ..AppSlot::new("tealeaf") },
                    AppSlot { weight: 2.0, ..AppSlot::new("clvleaf") },
                    AppSlot {
                        weight: 1.0,
                        policy: Some(PolicyConfig::Static { arm: 7 }),
                        ..AppSlot::new("lbm")
                    },
                    AppSlot { weight: 1.0, ..AppSlot::new("miniswp") },
                    AppSlot { weight: 1.0, ..AppSlot::new("weather") },
                ];
                // Short staggered budgets bound the wall-clock of every
                // requeue round — a killed worker's shard re-runs in
                // seconds, so chaos tests stay fast.
                s.arrivals = Arrivals::Staggered { phases: 3, min_frac: 0.3, base_steps: 5_000 };
                let base = SwitchCost::default();
                s.switch_costs = (0..3)
                    .map(|i| {
                        let m = (1 << i) as f64 + i as f64; // 1x, 3x, 6x
                        SwitchCost { latency_s: base.latency_s * m, energy_j: base.energy_j * m }
                    })
                    .collect();
            }
            _ => return None,
        }
        Some(s)
    }

    /// Validate the schedule against the calibrated suite.
    pub fn validate(&self) -> Result<(), String> {
        if self.slots.is_empty() {
            return Err("scenario has no app slots".into());
        }
        for slot in &self.slots {
            if calibration::app(&slot.app).is_none() {
                return Err(format!("unknown app: {}", slot.app));
            }
            if !(slot.weight > 0.0) {
                return Err(format!("app {}: weight must be > 0", slot.app));
            }
        }
        if let Arrivals::Staggered { phases, min_frac, base_steps } = self.arrivals {
            if phases == 0 {
                return Err("arrivals.phases must be >= 1".into());
            }
            if !(min_frac > 0.0 && min_frac <= 1.0) {
                return Err("arrivals.min_frac must be in (0, 1]".into());
            }
            if base_steps == 0 {
                return Err("arrivals.base_steps must be >= 1".into());
            }
        }
        for c in &self.switch_costs {
            if c.latency_s < 0.0 || c.energy_j < 0.0 {
                return Err("switch costs must be non-negative".into());
            }
        }
        Ok(())
    }

    /// Generate the assignment list for a fleet of `nodes` nodes.
    /// Deterministic and order-independent: assignment `n` is a pure
    /// function of `(self, n)`. Errors on an invalid schedule (unknown
    /// app, non-positive weight, degenerate arrivals).
    pub fn assignments(&self, nodes: usize) -> Result<Vec<NodeAssignment>, String> {
        self.validate()?;
        let weights: Vec<f64> = self.slots.iter().map(|s| s.weight).collect();
        Ok((0..nodes)
            .map(|n| {
                let mut draw = cell_rng(self.seed, n as u64);
                let slot = match self.pick {
                    Pick::RoundRobin => &self.slots[n % self.slots.len()],
                    Pick::Weighted => &self.slots[draw.weighted_index(&weights)],
                };
                let max_steps = match self.arrivals {
                    Arrivals::Uniform => None,
                    Arrivals::Staggered { phases, min_frac, base_steps } => {
                        let p = n % phases;
                        let frac = if phases == 1 {
                            1.0
                        } else {
                            min_frac + (1.0 - min_frac) * p as f64 / (phases - 1) as f64
                        };
                        Some(((base_steps as f64 * frac) as u64).max(1))
                    }
                };
                let switch_cost = if self.switch_costs.is_empty() {
                    None
                } else {
                    Some(self.switch_costs[draw.index(self.switch_costs.len())])
                };
                NodeAssignment {
                    node: n,
                    app: slot.app.clone(),
                    // Wrapping deliberately: boundary seeds must not panic
                    // in debug builds (mirrors `Leader::assign_round_robin`).
                    seed: self.seed.wrapping_add(n as u64),
                    max_steps,
                    policy: slot.policy.clone(),
                    switch_cost,
                    // Scenario presets share the session-default domain;
                    // per-node domains arrive via explicit assignments.
                    freqs_ghz: None,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_matches_legacy_assignment() {
        let s = ScenarioSchedule::round_robin(&["tealeaf", "clvleaf"], 100);
        let a = s.assignments(5).unwrap();
        assert_eq!(a.len(), 5);
        assert_eq!(a[0].app, "tealeaf");
        assert_eq!(a[1].app, "clvleaf");
        assert_eq!(a[4].app, "tealeaf");
        assert_eq!(a[3].seed, 103);
        assert!(a.iter().all(|x| x.max_steps.is_none()
            && x.policy.is_none()
            && x.switch_cost.is_none()));
    }

    #[test]
    fn all_presets_generate_valid_assignments() {
        for name in ["uniform", "mixed", "staggered", "hetero", "chaos"] {
            let s = ScenarioSchedule::preset(name, 7).unwrap();
            let a = s.assignments(32).unwrap();
            assert_eq!(a.len(), 32, "{name}");
            for x in &a {
                assert!(calibration::app(&x.app).is_some(), "{name}: {}", x.app);
            }
        }
        assert!(ScenarioSchedule::preset("bogus", 7).is_none());
    }

    #[test]
    fn generation_is_order_independent() {
        // Assignment n must not depend on how many nodes precede it.
        let s = ScenarioSchedule::preset("mixed", 11).unwrap();
        let small = s.assignments(8).unwrap();
        let large = s.assignments(64).unwrap();
        assert_eq!(small[..], large[..8]);
    }

    #[test]
    fn weighted_mix_tracks_weights() {
        let s = ScenarioSchedule::preset("mixed", 3).unwrap();
        let a = s.assignments(800).unwrap();
        let tea = a.iter().filter(|x| x.app == "tealeaf").count();
        // tealeaf carries 3/8 of the weight; allow generous sampling slack.
        assert!((tea as f64 / 800.0 - 3.0 / 8.0).abs() < 0.08, "{tea}");
    }

    #[test]
    fn staggered_budgets_span_the_configured_range() {
        let s = ScenarioSchedule::preset("staggered", 5).unwrap();
        let a = s.assignments(16).unwrap();
        let budgets: Vec<u64> = a.iter().map(|x| x.max_steps.unwrap()).collect();
        assert_eq!(budgets[0], 1_500); // 25 % of 6,000
        assert_eq!(budgets[3], 6_000); // 100 %
        assert_eq!(budgets[4], budgets[0]); // phases repeat mod 4
        assert!(budgets.iter().all(|b| (1_500..=6_000).contains(b)));
    }

    #[test]
    fn hetero_draws_costs_from_the_configured_set() {
        let s = ScenarioSchedule::preset("hetero", 9).unwrap();
        let a = s.assignments(64).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for x in &a {
            let c = x.switch_cost.unwrap();
            assert!(s.switch_costs.contains(&c));
            seen.insert((c.latency_s * 1e9) as u64);
        }
        assert_eq!(seen.len(), 3, "all three cost tiers should appear in 64 draws");
    }

    #[test]
    fn boundary_seeds_wrap_instead_of_panicking() {
        let s = ScenarioSchedule::round_robin(&["tealeaf"], u64::MAX);
        let a = s.assignments(3).unwrap();
        let seeds: Vec<u64> = a.iter().map(|x| x.seed).collect();
        assert_eq!(seeds, vec![u64::MAX, 0, 1]);
    }

    #[test]
    fn chaos_preset_is_short_mixed_and_hetero() {
        let s = ScenarioSchedule::preset("chaos", 3).unwrap();
        assert_eq!(s.pick, Pick::Weighted);
        assert_eq!(
            s.arrivals,
            Arrivals::Staggered { phases: 3, min_frac: 0.3, base_steps: 5_000 }
        );
        assert_eq!(s.switch_costs.len(), 3);
        let a = s.assignments(9).unwrap();
        // Every node is budget-capped (requeue rounds stay cheap) and
        // carries a drawn switch cost.
        assert!(a.iter().all(|x| x.max_steps.unwrap() <= 5_000 && x.switch_cost.is_some()));
    }

    #[test]
    fn invalid_schedules_are_rejected() {
        let mut s = ScenarioSchedule::round_robin(&["tealeaf"], 1);
        s.slots[0].weight = 0.0;
        assert!(s.validate().is_err());
        let mut s = ScenarioSchedule::round_robin(&["nope"], 1);
        assert!(s.validate().is_err());
        s = ScenarioSchedule::round_robin(&["tealeaf"], 1);
        s.arrivals = Arrivals::Staggered { phases: 0, min_frac: 0.5, base_steps: 100 };
        assert!(s.validate().is_err());
        // assignments() surfaces the same error instead of panicking.
        assert!(s.assignments(4).is_err());
    }
}
