//! Cluster worker: one thread = one simulated node under one controller.
//!
//! Policy driving happens through [`drive_hooked`] — the sans-IO
//! [`Controller`](crate::control::Controller) driven against a
//! [`SimBackend`](crate::control::SimBackend), with a read-only per-step
//! hook tapping the controller's live accounting — which steps the node's
//! controller through the shared batch policy core at B = 1
//! (EXPERIMENTS.md §Engine, §Controller) — the same
//! `select_into`/`update_batch` surface the fleet engines use, with no
//! per-step allocations on the trace-off path. The hook is where
//! heartbeats come from: beats are emitted *during* the run, so they are
//! a real liveness signal, while their total stays the pure
//! [`heartbeat_count`] at any job count. Because the decision core is
//! backend-agnostic, a cluster node could equally replay recorded
//! telemetry; the controller API keeps that choice out of this file.

use std::sync::mpsc::SyncSender;

use crate::bandit::Policy;
use crate::control::{drive_hooked, Controller, RunMetrics, SessionCfg, SimBackend};
use crate::workload::model::AppModel;

/// Telemetry events a worker streams to the leader.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkerEvent {
    /// Live heartbeat: (node_id, progress fraction, cum energy J).
    /// Emitted *during* the run every `heartbeat_steps` decisions (capped
    /// at [`MAX_HEARTBEATS`]), so a stalled node stops beating — the
    /// liveness signal the leader's read deadlines key off.
    Progress { node: usize, completed: f64, energy_j: f64 },
    /// Terminal event with the node's final metrics.
    Done { node: usize, result: NodeResult },
}

/// Upper bound on heartbeats per node (shared with [`heartbeat_count`]'s
/// clamp so streamed beats and the pure count never diverge).
pub const MAX_HEARTBEATS: u64 = 50;

/// Final per-node outcome.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeResult {
    pub node: usize,
    pub app: String,
    pub metrics: RunMetrics,
}

/// Number of heartbeats a node emits for a run of `steps` decisions.
/// A pure function of the run (never of scheduling), so the cluster-wide
/// heartbeat total is identical at any worker count. Clamped to [1, 50]:
/// every node emits at least a terminal beat — short budget-capped runs
/// (staggered arrivals) used to floor at 0 and were invisible to leader
/// telemetry.
pub fn heartbeat_count(steps: u64, heartbeat_steps: u64) -> u64 {
    (steps.max(1) / heartbeat_steps.max(1)).clamp(1, MAX_HEARTBEATS)
}

/// Run one node to completion, streaming progress events *while the
/// session runs* — one beat every `heartbeat_steps` decisions, tapped off
/// the controller's live accounting via [`drive_hooked`] — and returning
/// the final result (also mirrored onto the stream as a terminal
/// [`WorkerEvent::Done`]). The beat total is exactly
/// [`heartbeat_count`]`(steps, heartbeat_steps)`: runs shorter than one
/// interval emit a single terminal beat after the drive, so cluster-wide
/// heartbeat totals stay a pure function of the schedule. Blocking — call
/// from a worker thread.
pub fn run_node(
    node: usize,
    app: &AppModel,
    mut policy: Box<dyn Policy>,
    cfg: &SessionCfg,
    heartbeat_steps: u64,
    tx: &SyncSender<WorkerEvent>,
) -> NodeResult {
    let hb = heartbeat_steps.max(1);
    let mut beats = 0u64;
    // Last observed (completed, energy) — feeds the terminal beat when a
    // budget-capped run never crosses a heartbeat interval.
    let mut latest = (0.0f64, 0.0f64);
    let mut leader_gone = false;
    let mut backend = SimBackend::new(app, cfg);
    let controller = Controller::new(app, policy.as_mut(), cfg);
    let result = drive_hooked(controller, &mut backend, &mut |c| {
        latest = (c.completed(0), c.true_energy_j(0));
        if c.steps() % hb == 0 && beats < MAX_HEARTBEATS && !leader_gone {
            beats += 1;
            // Backpressure: block until the leader drains.
            leader_gone = tx
                .send(WorkerEvent::Progress {
                    node,
                    completed: latest.0.clamp(0.0, 1.0),
                    energy_j: latest.1,
                })
                .is_err();
        }
    })
    .expect("simulated backend is infallible")
    .pop()
    .expect("B = 1 drive yields exactly one result");
    let out = NodeResult { node, app: app.name.to_string(), metrics: result.metrics };
    if leader_gone {
        return out; // leader hung up mid-run; the result still reaches the pool
    }
    if beats == 0 {
        // Short run (fewer steps than one interval): the terminal beat
        // keeps every node visible to leader telemetry.
        let _ = tx.send(WorkerEvent::Progress {
            node,
            completed: latest.0.clamp(0.0, 1.0),
            energy_j: latest.1,
        });
    }
    let _ = tx.send(WorkerEvent::Done { node, result: out.clone() });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::StaticPolicy;
    use crate::workload::calibration;
    use std::sync::mpsc;

    #[test]
    fn worker_streams_progress_then_done() {
        let app = calibration::app("clvleaf").unwrap();
        let (tx, rx) = mpsc::sync_channel(64);
        let cfg = SessionCfg::default();
        let handle = std::thread::spawn(move || {
            run_node(3, &app, Box::new(StaticPolicy::new(9, 8)), &cfg, 500, &tx)
        });
        let mut progress_events = 0;
        let mut done = None;
        for event in rx {
            match event {
                WorkerEvent::Progress { node, completed, energy_j } => {
                    assert_eq!(node, 3);
                    assert!(completed > 0.0 && completed <= 1.0);
                    assert!(energy_j >= 0.0);
                    progress_events += 1;
                }
                WorkerEvent::Done { node, result } => {
                    assert_eq!(node, 3);
                    done = Some(result);
                }
            }
        }
        let returned = handle.join().unwrap();
        assert!(progress_events > 0);
        assert_eq!(progress_events, heartbeat_count(returned.metrics.steps, 500));
        let result = done.expect("Done event");
        assert_eq!(result.app, "clvleaf");
        assert!((result.metrics.gpu_energy_kj - 100.65).abs() < 1.0);
        // The returned result and the streamed Done event agree.
        assert_eq!(returned.metrics.gpu_energy_kj, result.metrics.gpu_energy_kj);
        assert_eq!(returned.metrics.steps, result.metrics.steps);
    }

    #[test]
    fn heartbeat_count_is_pure_and_capped() {
        assert_eq!(heartbeat_count(10_000, 1_000), 10);
        // Runs shorter than one heartbeat interval still emit the
        // terminal beat (regression: budget-capped nodes were invisible).
        assert_eq!(heartbeat_count(999, 1_000), 1);
        assert_eq!(heartbeat_count(150, 1_000), 1);
        assert_eq!(heartbeat_count(1_000_000, 1_000), 50);
        assert_eq!(heartbeat_count(0, 0), 1); // degenerate inputs clamp to 1/1
    }

    #[test]
    fn short_runs_emit_exactly_one_terminal_progress_beat() {
        let app = calibration::app("tealeaf").unwrap();
        let (tx, rx) = mpsc::sync_channel(8);
        // 50-step budget with 1,000-step heartbeats: pre-fix, zero
        // Progress events reached the leader.
        let cfg = SessionCfg { max_steps: 50, ..SessionCfg::default() };
        let handle = std::thread::spawn(move || {
            run_node(1, &app, Box::new(StaticPolicy::new(9, 8)), &cfg, 1_000, &tx)
        });
        let events: Vec<WorkerEvent> = rx.iter().collect();
        handle.join().unwrap();
        let beats: Vec<f64> = events
            .iter()
            .filter_map(|e| match e {
                WorkerEvent::Progress { completed, .. } => Some(*completed),
                WorkerEvent::Done { .. } => None,
            })
            .collect();
        assert_eq!(beats.len(), 1, "exactly one terminal beat: {beats:?}");
        // The terminal beat reports the *actual* completed fraction —
        // a 50-step capped run is nowhere near done.
        assert!(beats[0] > 0.0 && beats[0] < 1.0, "{}", beats[0]);
        assert!(matches!(events.last(), Some(WorkerEvent::Done { .. })));
    }
}
