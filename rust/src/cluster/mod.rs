//! Multi-node orchestration: the leader/worker runtime that scales the
//! per-node controller to a fleet of simulated Aurora nodes.
//!
//! The paper evaluates one node; production deployment (its §1 impact
//! claim assumes all 10,620 nodes) needs a coordinator that launches one
//! controller per node, streams their telemetry, and aggregates
//! energy/savings across the job. This module provides that L3 runtime:
//! std::thread workers (tokio is not in the offline crate set), a bounded
//! mpsc telemetry channel with backpressure, and a leader that merges
//! per-node results deterministically.
//!
//! Scheduling runs on the deterministic work-stealing executor
//! (`exec::run_indexed`), so a straggler node never idles the rest of the
//! pool and the merged report is byte-identical at any `--jobs` value.
//! The [`ScenarioSchedule`] layer generates assignment mixes beyond
//! round-robin: weighted app mixes, staggered arrivals, per-app policy
//! overrides, and heterogeneous per-node switch costs.
//!
//! Beyond one process, the leader shards the fleet across worker
//! processes and hosts: [`transport`] abstracts *how* a contiguous shard
//! executes (in-process pool, framed-JSONL pipe to a subprocess, or TCP
//! socket to a remote `cluster-worker --connect`), [`wire`] is the
//! serde-free codec those frames ride on, and the merged report stays
//! byte-identical across `--shards` × `--jobs` × transport — including
//! runs where a worker dies mid-shard and the leader requeues its
//! assignments onto survivors (EXPERIMENTS.md §Cluster).

pub mod leader;
pub mod schedule;
pub mod transport;
pub mod wire;
pub mod worker;

pub use leader::{ClusterConfig, ClusterReport, Leader, NodeAssignment};
pub use schedule::{AppSlot, Arrivals, Pick, ScenarioSchedule};
pub use transport::{InProcess, Subprocess, Tcp, Transport, DEFAULT_SHARD_TIMEOUT};
pub use wire::{Frame, WireCodec, WireError};
pub use worker::{NodeResult, WorkerEvent};
