//! Cluster leader: partition node assignments into shards, fan the shards
//! out through a [`Transport`] (in-process pool or `cluster-worker`
//! subprocesses over framed JSONL), and merge the event streams
//! deterministically.
//!
//! Scheduling follows the executor contract (EXPERIMENTS.md §Executor):
//! each node plan is a pure function of its assignment, the transport and
//! `exec::run_indexed` decide only *when and where* a node runs, and the
//! merge happens in stable node-id order on the leader thread — so the
//! [`ClusterReport`] is byte-identical at any `--jobs` value, at any
//! `--shards` value, and across transports. A legacy fixed-wave scheduler
//! is kept as [`Leader::run_waves`]: it produces the identical report
//! (same plans, same merge) and serves as the cross-check reference and
//! the wall-clock baseline the work-stealing path must beat on
//! mixed-duration scenarios (see EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::config::PolicyConfig;
use crate::control::SessionCfg;
use crate::exec::available_jobs;
use crate::sim::freq::{FreqDomain, SwitchCost};
use crate::telemetry::Recorder;
use crate::util::io::Csv;
use crate::util::stats::Welford;
use crate::util::table::{fnum, fnum_sep, Table};
use crate::workload::calibration;
use crate::workload::model::AppModel;

use super::transport::{partition, InProcess, Transport};
use super::worker::{self, NodeResult, WorkerEvent};

/// One node's job: which app it runs, its seed, and optional per-node
/// overrides (scenario layer: step budget, policy, switch cost).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeAssignment {
    pub node: usize,
    pub app: String,
    pub seed: u64,
    /// Step budget override (staggered arrivals); `None` = run to
    /// completion under the session default cap.
    pub max_steps: Option<u64>,
    /// Policy override for this node; `None` = the cluster default.
    pub policy: Option<PolicyConfig>,
    /// Per-node DVFS transition cost (heterogeneous fleets); `None` = the
    /// cluster session default.
    pub switch_cost: Option<SwitchCost>,
    /// Per-node frequency-domain override (ascending GHz arm set);
    /// `None` = the cluster session default. Makes heterogeneous
    /// frequency domains per node expressible on the wire; the domain
    /// length must match the app's calibration table (9 for the shipped
    /// suite).
    pub freqs_ghz: Option<Vec<f64>>,
}

impl NodeAssignment {
    /// A plain assignment with no per-node overrides.
    pub fn new(node: usize, app: &str, seed: u64) -> NodeAssignment {
        NodeAssignment {
            node,
            app: app.to_string(),
            seed,
            max_steps: None,
            policy: None,
            switch_cost: None,
            freqs_ghz: None,
        }
    }
}

/// Cluster run configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker threads for the node pool (work-stealing; also the wave
    /// width of the legacy scheduler).
    pub jobs: usize,
    /// Default policy, overridable per assignment.
    pub policy: PolicyConfig,
    /// Base session settings (seed and per-node overrides applied on top).
    pub session: SessionCfg,
    /// Decisions between progress heartbeats.
    pub heartbeat_steps: u64,
    /// How many requeue rounds [`Leader::run_sharded`] may spend
    /// re-running failed shards on surviving backends before giving up
    /// (0 = fail on the first shard loss).
    pub shard_retries: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            jobs: available_jobs(),
            policy: PolicyConfig::EnergyUcb(crate::bandit::energyucb::EnergyUcbConfig::default()),
            session: SessionCfg::default(),
            heartbeat_steps: 1_000,
            shard_retries: 2,
        }
    }
}

/// Aggregated outcome of a cluster run.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Per-node results, ordered by node id (deterministic merge).
    pub nodes: Vec<NodeResult>,
    /// Total GPU energy across nodes, kJ.
    pub total_energy_kj: f64,
    /// Total saved vs per-app 1.6 GHz defaults, kJ (budget-capped nodes
    /// compare against the same fraction of the default-frequency run).
    pub total_saved_kj: f64,
    /// Progress heartbeats observed (telemetry-stream health).
    pub heartbeats: u64,
    /// Per-app energy statistics across nodes.
    pub per_app: BTreeMap<String, (u64, f64, f64)>, // (count, mean kJ, std kJ)
}

impl ClusterReport {
    /// Deterministic text report (no wall-clock — timing goes to stderr so
    /// stdout stays byte-identical across `--jobs`).
    pub fn render(&self) -> String {
        let mut table = Table::new(vec!["app", "nodes", "mean kJ", "std kJ"]);
        for (app, (count, mean, std)) in &self.per_app {
            table.row(vec![app.clone(), count.to_string(), fnum_sep(*mean, 2), fnum(*std, 2)]);
        }
        format!(
            "{}total GPU energy {} kJ, saved vs 1.6 GHz defaults {} kJ \
             ({} nodes, {} telemetry heartbeats)\n",
            table.render(),
            fnum_sep(self.total_energy_kj, 1),
            fnum_sep(self.total_saved_kj, 1),
            self.nodes.len(),
            self.heartbeats
        )
    }

    /// Per-node CSV (node-id order, deterministic).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new();
        csv.row(&["node", "app", "energy_kj", "time_s", "switches", "steps"]);
        for r in &self.nodes {
            csv.row(&[
                r.node.to_string(),
                r.app.clone(),
                format!("{:.6}", r.metrics.gpu_energy_kj),
                format!("{:.6}", r.metrics.exec_time_s),
                r.metrics.switches.to_string(),
                r.metrics.steps.to_string(),
            ]);
        }
        csv
    }
}

/// A fully resolved, validated per-node execution plan. Built once, up
/// front, so the schedulers never clone configs or resolve apps mid-run.
pub(crate) struct NodePlan {
    pub(crate) node: usize,
    pub(crate) app: AppModel,
    pub(crate) policy: PolicyConfig,
    pub(crate) session: SessionCfg,
}

/// Validate and resolve every assignment into an executable plan. All
/// fallible work (unknown apps, duplicate node ids, switch-latency
/// guards) happens here, before any thread or subprocess spawns. A free
/// function because every execution surface resolves through it: the
/// leader (whole-batch validation), the shard transports, and the
/// `cluster-worker` binary (per-shard plans).
pub(crate) fn resolve_plans(
    cfg: &ClusterConfig,
    assignments: &[NodeAssignment],
) -> anyhow::Result<Vec<NodePlan>> {
    let mut seen = std::collections::BTreeSet::new();
    assignments
        .iter()
        .map(|a| {
            if !seen.insert(a.node) {
                anyhow::bail!("duplicate node id {}", a.node);
            }
            let app = calibration::app(&a.app)
                .ok_or_else(|| anyhow::anyhow!("unknown app {}", a.app))?;
            let base = &cfg.session;
            let freqs = match &a.freqs_ghz {
                Some(ghz) => FreqDomain::try_new(ghz.clone())
                    .map_err(|e| anyhow::anyhow!("node {}: {e}", a.node))?,
                None => base.freqs.clone(),
            };
            if freqs.k() != app.energy_kj.len() {
                anyhow::bail!(
                    "node {}: frequency domain has {} arms but app {} is calibrated for {}",
                    a.node,
                    freqs.k(),
                    a.app,
                    app.energy_kj.len()
                );
            }
            let session = SessionCfg {
                seed: a.seed,
                max_steps: a.max_steps.unwrap_or(base.max_steps),
                switch_cost: a.switch_cost.unwrap_or(base.switch_cost),
                freqs,
                ..base.clone()
            };
            if session.switch_cost.latency_s >= session.dt_s {
                anyhow::bail!(
                    "node {}: switch latency {}s >= decision interval {}s",
                    a.node,
                    session.switch_cost.latency_s,
                    session.dt_s
                );
            }
            let policy = a.policy.clone().unwrap_or_else(|| cfg.policy.clone());
            if let PolicyConfig::Static { arm } = &policy {
                if *arm >= session.freqs.k() {
                    anyhow::bail!(
                        "node {}: static arm {arm} out of range (K = {})",
                        a.node,
                        session.freqs.k()
                    );
                }
            }
            Ok(NodePlan { node: a.node, app, policy, session })
        })
        .collect()
}

/// The cluster leader.
pub struct Leader {
    cfg: ClusterConfig,
}

impl Leader {
    pub fn new(cfg: ClusterConfig) -> Leader {
        assert!(cfg.jobs > 0);
        Leader { cfg }
    }

    /// Round-robin assignment of `nodes` over `apps`, seeds derived from
    /// `seed0 + node` (wrapping deliberately: seeds near `u64::MAX` wrap
    /// to the low range instead of panicking in debug builds — every
    /// seed in a batch stays distinct as long as `nodes <= 2^64`).
    /// Infallible like the pre-scenario API — app names are validated
    /// when the leader runs, not here; richer mixes come from
    /// [`super::ScenarioSchedule`].
    pub fn assign_round_robin(apps: &[&str], nodes: usize, seed0: u64) -> Vec<NodeAssignment> {
        assert!(!apps.is_empty(), "assign_round_robin: no apps");
        (0..nodes)
            .map(|n| NodeAssignment::new(n, apps[n % apps.len()], seed0.wrapping_add(n as u64)))
            .collect()
    }

    /// Execute all assignments on the in-process work-stealing pool;
    /// blocks until completion. Report is byte-identical at any `jobs`
    /// value. Shorthand for `run_sharded(assignments, 1, &InProcess)` —
    /// the single code path all transports share.
    pub fn run(&self, assignments: &[NodeAssignment]) -> anyhow::Result<ClusterReport> {
        self.run_sharded(assignments, 1, &InProcess)
    }

    /// Partition the assignments into `shards` deterministic contiguous
    /// shards, execute every shard through `transport` (all shards in
    /// flight at once, one leader thread each), and merge the
    /// `WorkerEvent` streams in stable node-id order. The report is
    /// byte-identical for any `(shards, transport, jobs)` combination —
    /// the extended determinism contract (EXPERIMENTS.md §Cluster):
    /// heartbeats are an order-independent sum, and the merge fixes the
    /// floating-point accumulation order by sorting on node id.
    ///
    /// Fault tolerance: when a shard's transport fails (worker death,
    /// socket drop, read deadline), the whole shard's assignments are
    /// requeued and re-partitioned over whatever capacity the transport
    /// still reports (surviving TCP workers; unchanged for process-local
    /// backends), up to [`ClusterConfig::shard_retries`] extra rounds.
    /// A failed shard contributes *no* events — its partial stream is
    /// discarded wholesale and every one of its nodes re-runs from its
    /// seed — so a recovered run merges the exact event multiset of a
    /// failure-free one and the report stays byte-identical.
    pub fn run_sharded(
        &self,
        assignments: &[NodeAssignment],
        shards: usize,
        transport: &dyn Transport,
    ) -> anyhow::Result<ClusterReport> {
        if shards == 0 {
            anyhow::bail!("shards must be >= 1");
        }
        // Validate the whole batch leader-side before anything spawns.
        // Not just a nicety: duplicate node ids landing in *different*
        // shards are invisible to the per-shard resolve, and a bad app
        // name should fail here, not as a subprocess error frame. The
        // per-node resolve work is repeated inside each shard, but it is
        // string lookups and config clones — noise next to the sessions.
        // The resolved per-node frequency domains also feed the merge's
        // saved-energy baseline (heterogeneous domains are expressible).
        let domains = node_domains(&resolve_plans(&self.cfg, assignments)?);
        let mut telemetry = Recorder::new();
        let mut results = Vec::with_capacity(assignments.len());
        let mut pending: Vec<NodeAssignment> = assignments.to_vec();
        let mut failures: Vec<String> = Vec::new();
        let mut round = 0usize;
        while !pending.is_empty() {
            // Round 0 fans out at the requested width regardless of what
            // `capacity()` says — TCP workers connect asynchronously, so
            // an early poll would undercount them; the per-shard accept
            // deadline is the authoritative "did anyone show up" check.
            // Requeue rounds shrink to the surviving capacity instead of
            // re-offering work to a width that just lost members.
            let want = if round == 0 {
                shards
            } else {
                match transport.capacity() {
                    Some(0) => anyhow::bail!(
                        "no surviving {} workers to requeue {} node(s) onto (after: {})",
                        transport.name(),
                        pending.len(),
                        failures.join("; ")
                    ),
                    Some(cap) => shards.min(cap),
                    None => shards,
                }
            };
            let requeue = {
                let parts = partition(&pending, want);
                // Divide the worker-thread budget across the concurrent
                // shards (ceiling, so every shard keeps >= 1 thread): K
                // shards each running the full `jobs`-wide pool would
                // oversubscribe the machine K-fold. Harmless to the
                // report — it is byte-identical at any thread count.
                let per_shard = parts.len().max(1);
                let shard_cfg = ClusterConfig {
                    jobs: (self.cfg.jobs + per_shard - 1) / per_shard,
                    ..self.cfg.clone()
                };
                let outcomes: Vec<anyhow::Result<Vec<WorkerEvent>>> =
                    std::thread::scope(|scope| {
                        let shard_cfg = &shard_cfg;
                        let handles: Vec<_> = parts
                            .iter()
                            .map(|part| scope.spawn(move || transport.run_shard(shard_cfg, part)))
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| {
                                h.join().unwrap_or_else(|_| {
                                    Err(anyhow::anyhow!("shard thread panicked"))
                                })
                            })
                            .collect()
                    });
                let mut requeue: Vec<NodeAssignment> = Vec::new();
                for (i, outcome) in outcomes.into_iter().enumerate() {
                    match outcome {
                        Ok(events) => {
                            for ev in events {
                                record_event(&mut telemetry, &ev);
                                if let WorkerEvent::Done { result, .. } = ev {
                                    results.push(result);
                                }
                            }
                        }
                        Err(e) => {
                            // Discard the failed shard's stream wholesale
                            // (run_shard returned no events) and requeue
                            // every node it owned.
                            telemetry.counter("cluster.shard_failures").inc();
                            telemetry
                                .counter("cluster.requeued_nodes")
                                .add(parts[i].len() as u64);
                            failures.push(format!("round {round} shard {i}: {e:#}"));
                            requeue.extend(parts[i].iter().cloned());
                        }
                    }
                }
                requeue
            };
            if requeue.is_empty() {
                break;
            }
            if round >= self.cfg.shard_retries {
                anyhow::bail!(
                    "{} node(s) still unplaced after {} requeue round(s): {}",
                    requeue.len(),
                    round,
                    failures.join("; ")
                );
            }
            eprintln!(
                "cluster: requeueing {} node(s) after shard failure ({})",
                requeue.len(),
                failures.last().map(String::as_str).unwrap_or("?")
            );
            pending = requeue;
            round += 1;
        }
        if results.len() != assignments.len() {
            anyhow::bail!(
                "sharded run returned {} node results, expected {}",
                results.len(),
                assignments.len()
            );
        }
        merge(results, &telemetry, &domains)
    }

    /// Legacy fixed-wave scheduler: chunk the plans into waves of `jobs`
    /// threads and join each wave before starting the next. Produces the
    /// identical report (same plans, same merge) but idles behind each
    /// wave's straggler — kept as the cross-check reference and perf
    /// baseline for the work-stealing path.
    pub fn run_waves(&self, assignments: &[NodeAssignment]) -> anyhow::Result<ClusterReport> {
        let plans = resolve_plans(&self.cfg, assignments)?;
        let domains = node_domains(&plans);
        // Node-id -> result-slot map, precomputed once (the drain loop
        // previously searched the assignment list per Done event: O(n^2)).
        let slot_of: BTreeMap<usize, usize> =
            plans.iter().enumerate().map(|(i, p)| (p.node, i)).collect();
        let mut results: Vec<Option<NodeResult>> = (0..plans.len()).map(|_| None).collect();
        let mut telemetry = Recorder::new();

        for wave in plans.chunks(self.cfg.jobs) {
            std::thread::scope(|scope| -> anyhow::Result<()> {
                // One channel per wave, and the leader's own sender is
                // dropped before draining: once every worker thread has
                // finished (or unwound from a panic, dropping its clone),
                // the channel closes and `recv` returns Err instead of
                // blocking forever. The previous wave-spanning channel
                // kept a live leader `tx`, so one panicked worker — gone
                // without its Done — deadlocked the
                // `while done_in_wave < wave.len()` drain.
                let (tx, rx) = mpsc::sync_channel::<WorkerEvent>(256);
                let mut handles = Vec::new();
                for p in wave {
                    let tx = tx.clone();
                    let hb = self.cfg.heartbeat_steps;
                    handles.push(scope.spawn(move || {
                        // Policy arity follows the plan's own frequency
                        // domain (per-node domains are expressible).
                        let policy = p.policy.build(p.session.freqs.k(), p.session.seed);
                        worker::run_node(p.node, &p.app, policy, &p.session, hb, &tx)
                    }));
                }
                drop(tx);
                // Drain while this wave runs: the channel closes when the
                // last worker exits, panicked or not.
                let mut done_in_wave = 0;
                for ev in rx {
                    record_event(&mut telemetry, &ev);
                    if let WorkerEvent::Done { node, result } = ev {
                        results[slot_of[&node]] = Some(result);
                        done_in_wave += 1;
                    }
                }
                let mut panicked = 0;
                for h in handles {
                    if h.join().is_err() {
                        panicked += 1;
                    }
                }
                if panicked > 0 || done_in_wave < wave.len() {
                    anyhow::bail!(
                        "wave worker panicked before completing its node \
                         ({done_in_wave}/{} done, {panicked} panicked)",
                        wave.len()
                    );
                }
                Ok(())
            })?;
        }

        let results: Vec<NodeResult> =
            results.into_iter().map(|r| r.expect("all nodes done")).collect();
        merge(results, &telemetry, &domains)
    }
}

/// Node-id → resolved frequency domain map (for the merge's per-node
/// saved-energy baseline).
fn node_domains(plans: &[NodePlan]) -> BTreeMap<usize, FreqDomain> {
    plans.iter().map(|p| (p.node, p.session.freqs.clone())).collect()
}

/// Fold a worker event into the telemetry recorder (heartbeat stream).
fn record_event(telemetry: &mut Recorder, ev: &WorkerEvent) {
    match ev {
        WorkerEvent::Progress { energy_j, .. } => {
            telemetry.counter("cluster.heartbeats").inc();
            telemetry.gauge("cluster.progress_energy_j").record(*energy_j);
        }
        WorkerEvent::Done { .. } => telemetry.counter("cluster.nodes_done").inc(),
    }
}

/// Stable merge: order by node id, then aggregate in that fixed order so
/// floating-point totals are independent of completion order.
fn merge(
    mut nodes: Vec<NodeResult>,
    telemetry: &Recorder,
    domains: &BTreeMap<usize, FreqDomain>,
) -> anyhow::Result<ClusterReport> {
    nodes.sort_by_key(|r| r.node);
    let mut total = 0.0;
    let mut saved = 0.0;
    let mut per_app_acc: BTreeMap<String, Welford> = BTreeMap::new();
    for r in &nodes {
        total += r.metrics.gpu_energy_kj;
        let app = calibration::app(&r.app).expect("resolved app");
        // Budget-capped nodes (staggered arrivals) ran only part of the
        // job; `saved_energy_kj` scales the default-frequency baseline by
        // the true completed work fraction so "saved" compares like with
        // like (the metric owns the scaling since the RunMetrics fix).
        // The baseline's max arm comes from the node's own resolved
        // domain, not a hard-coded Aurora (heterogeneous fleets).
        let freqs = domains.get(&r.node).expect("resolved plan for every result");
        saved += r.metrics.saved_energy_kj(&app, freqs);
        per_app_acc.entry(r.app.clone()).or_default().push(r.metrics.gpu_energy_kj);
    }
    let per_app = per_app_acc
        .into_iter()
        .map(|(k, w)| (k, (w.count(), w.mean(), w.sample_std())))
        .collect();
    Ok(ClusterReport {
        nodes,
        total_energy_kj: total,
        total_saved_kj: saved,
        heartbeats: telemetry.counter_value("cluster.heartbeats").unwrap_or(0),
        per_app,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_round_robin() {
        let a = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 5, 100);
        assert_eq!(a.len(), 5);
        assert_eq!(a[0].app, "tealeaf");
        assert_eq!(a[1].app, "clvleaf");
        assert_eq!(a[4].app, "tealeaf");
        assert_eq!(a[3].seed, 103);
    }

    #[test]
    fn assignment_seeds_wrap_at_the_u64_boundary() {
        // seed0 near u64::MAX: `seed0 + n` used to panic in debug builds
        // and wrap silently in release; now it wraps deliberately and the
        // seeds stay distinct across the boundary.
        let a = Leader::assign_round_robin(&["tealeaf"], 3, u64::MAX - 1);
        let seeds: Vec<u64> = a.iter().map(|x| x.seed).collect();
        assert_eq!(seeds, vec![u64::MAX - 1, u64::MAX, 0]);
    }

    #[test]
    fn wave_worker_panic_is_a_clean_error_not_a_deadlock() {
        // One node's policy panics mid-run: the wave drain must observe
        // the closed channel and bail instead of blocking forever on a
        // Done event that will never come (the leader's own tx used to
        // keep the channel open).
        let leader = Leader::new(ClusterConfig {
            jobs: 3,
            session: SessionCfg { max_steps: 200, ..SessionCfg::default() },
            ..ClusterConfig::default()
        });
        let mut a = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 3, 5);
        a[1].policy = Some(PolicyConfig::PanicAfter { after: 5 });
        let err = leader.run_waves(&a).unwrap_err();
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
    }

    #[test]
    fn sharded_run_surfaces_in_process_panics_after_retries() {
        // An in-process shard whose policy panics fails deterministically
        // every requeue round; the leader must give up with a clean error
        // (bounded retries), never hang.
        let leader = Leader::new(ClusterConfig {
            jobs: 2,
            shard_retries: 1,
            session: SessionCfg { max_steps: 200, ..SessionCfg::default() },
            ..ClusterConfig::default()
        });
        let mut a = Leader::assign_round_robin(&["tealeaf"], 4, 5);
        a[2].policy = Some(PolicyConfig::PanicAfter { after: 5 });
        let err = leader.run_sharded(&a, 2, &InProcess).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("requeue round"), "{msg}");
        assert!(msg.contains("panicked"), "{msg}");
    }

    #[test]
    fn sharded_run_requeues_nothing_on_success() {
        // shard_retries = 0 must not affect healthy runs.
        let leader = Leader::new(ClusterConfig {
            jobs: 2,
            shard_retries: 0,
            session: SessionCfg { max_steps: 300, ..SessionCfg::default() },
            ..ClusterConfig::default()
        });
        let a = Leader::assign_round_robin(&["tealeaf"], 3, 9);
        let report = leader.run_sharded(&a, 2, &InProcess).unwrap();
        assert_eq!(report.nodes.len(), 3);
    }

    #[test]
    fn cluster_runs_nodes_in_parallel_and_merges() {
        let cfg = ClusterConfig { jobs: 4, heartbeat_steps: 2_000, ..ClusterConfig::default() };
        let leader = Leader::new(cfg);
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 6, 42);
        let report = leader.run(&assignments).unwrap();
        assert_eq!(report.nodes.len(), 6);
        // Deterministic order by node id.
        for (i, r) in report.nodes.iter().enumerate() {
            assert_eq!(r.node, i);
        }
        assert!(report.heartbeats > 0);
        // Energy in calibrated range per app.
        let (n_tea, mean_tea, _) = report.per_app["tealeaf"];
        assert_eq!(n_tea, 3);
        assert!(mean_tea > 95.0 && mean_tea < 108.0, "{mean_tea}");
        // Saved energy positive overall (EnergyUCB on these apps).
        assert!(report.total_saved_kj > 0.0);
    }

    #[test]
    fn cluster_is_deterministic_given_seeds() {
        let mk = |jobs| {
            let leader = Leader::new(ClusterConfig { jobs, ..ClusterConfig::default() });
            let assignments = Leader::assign_round_robin(&["clvleaf"], 4, 7);
            leader.run(&assignments).unwrap().total_energy_kj
        };
        assert_eq!(mk(2), mk(2));
        assert_eq!(mk(1), mk(4));
    }

    #[test]
    fn per_node_overrides_apply() {
        let leader = Leader::new(ClusterConfig { jobs: 2, ..ClusterConfig::default() });
        let mut a = Leader::assign_round_robin(&["clvleaf"], 2, 7);
        a[1].max_steps = Some(500);
        a[1].policy = Some(PolicyConfig::Static { arm: 8 });
        let report = leader.run(&a).unwrap();
        assert_eq!(report.nodes[1].metrics.steps, 500);
        assert_eq!(report.nodes[1].metrics.policy, "Static[arm 8]");
        assert_eq!(report.nodes[1].metrics.switches, 0);
        assert!(report.nodes[0].metrics.steps > 500);
    }

    #[test]
    fn unknown_app_is_an_error() {
        let leader = Leader::new(ClusterConfig::default());
        let bad = vec![NodeAssignment::new(0, "nope", 1)];
        assert!(leader.run(&bad).is_err());
    }

    #[test]
    fn duplicate_node_ids_are_an_error() {
        let leader = Leader::new(ClusterConfig::default());
        let bad = vec![NodeAssignment::new(3, "tealeaf", 1), NodeAssignment::new(3, "tealeaf", 2)];
        assert!(leader.run(&bad).is_err());
    }

    #[test]
    fn in_process_sharding_matches_the_unsharded_pool() {
        let leader = Leader::new(ClusterConfig {
            jobs: 2,
            heartbeat_steps: 1_500,
            ..ClusterConfig::default()
        });
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 5, 42);
        let pool = leader.run(&assignments).unwrap();
        for shards in [2, 3, 5, 9] {
            let sharded = leader.run_sharded(&assignments, shards, &InProcess).unwrap();
            assert_eq!(sharded.render(), pool.render(), "shards={shards}");
            assert_eq!(sharded.to_csv().render(), pool.to_csv().render(), "shards={shards}");
        }
        assert!(leader.run_sharded(&assignments, 0, &InProcess).is_err());
    }

    #[test]
    fn waves_and_stealing_agree() {
        let leader = Leader::new(ClusterConfig {
            jobs: 3,
            heartbeat_steps: 1_500,
            ..ClusterConfig::default()
        });
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 5, 42);
        let a = leader.run(&assignments).unwrap();
        let b = leader.run_waves(&assignments).unwrap();
        assert_eq!(a.render(), b.render());
        assert_eq!(a.to_csv().render(), b.to_csv().render());
    }
}
