//! Cluster leader: fan out node assignments to a bounded worker pool,
//! drain the telemetry stream, and merge results deterministically.

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::bandit::Policy;
use crate::config::PolicyConfig;
use crate::control::SessionCfg;
use crate::sim::freq::FreqDomain;
use crate::util::stats::Welford;
use crate::workload::calibration;

use super::worker::{self, NodeResult, WorkerEvent};

/// One node's job: which app it runs and its seed.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeAssignment {
    pub node: usize,
    pub app: String,
    pub seed: u64,
}

/// Cluster run configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Maximum worker threads (bounded pool).
    pub parallelism: usize,
    /// Policy to instantiate per node.
    pub policy: PolicyConfig,
    /// Base session settings (seed overridden per assignment).
    pub session: SessionCfg,
    /// Decisions between progress heartbeats.
    pub heartbeat_steps: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            parallelism: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            policy: PolicyConfig::EnergyUcb(crate::bandit::energyucb::EnergyUcbConfig::default()),
            session: SessionCfg::default(),
            heartbeat_steps: 1_000,
        }
    }
}

/// Aggregated outcome of a cluster run.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Per-node results, ordered by node id (deterministic merge).
    pub nodes: Vec<NodeResult>,
    /// Total GPU energy across nodes, kJ.
    pub total_energy_kj: f64,
    /// Total saved vs per-app 1.6 GHz defaults, kJ.
    pub total_saved_kj: f64,
    /// Progress heartbeats observed (telemetry-stream health).
    pub heartbeats: u64,
    /// Per-app energy statistics across nodes.
    pub per_app: BTreeMap<String, (u64, f64, f64)>, // (count, mean kJ, std kJ)
}

/// The cluster leader.
pub struct Leader {
    cfg: ClusterConfig,
}

impl Leader {
    pub fn new(cfg: ClusterConfig) -> Leader {
        assert!(cfg.parallelism > 0);
        Leader { cfg }
    }

    /// Round-robin assignment of `nodes` over `apps`, seeds derived from
    /// `seed0 + node`.
    pub fn assign_round_robin(apps: &[&str], nodes: usize, seed0: u64) -> Vec<NodeAssignment> {
        (0..nodes)
            .map(|n| NodeAssignment {
                node: n,
                app: apps[n % apps.len()].to_string(),
                seed: seed0 + n as u64,
            })
            .collect()
    }

    /// Execute all assignments; blocks until completion.
    pub fn run(&self, assignments: &[NodeAssignment]) -> anyhow::Result<ClusterReport> {
        let freqs = FreqDomain::aurora();
        let (tx, rx) = mpsc::sync_channel::<WorkerEvent>(256);
        let mut results: Vec<Option<NodeResult>> = vec![None; assignments.len()];
        let mut heartbeats = 0u64;

        // Bounded pool: chunk assignments into waves of `parallelism`.
        // (A work-stealing queue would be overkill: nodes are ~equal cost.)
        for wave in assignments.chunks(self.cfg.parallelism) {
            let mut handles = Vec::new();
            for a in wave {
                let app = calibration::app(&a.app)
                    .ok_or_else(|| anyhow::anyhow!("unknown app {}", a.app))?;
                let policy: Box<dyn Policy> = self
                    .build_policy_cfg()
                    .build_policy(freqs.k(), a.seed);
                let cfg = SessionCfg { seed: a.seed, ..self.cfg.session.clone() };
                let tx = tx.clone();
                let node = a.node;
                let hb = self.cfg.heartbeat_steps;
                handles.push(std::thread::spawn(move || {
                    worker::run_node(node, &app, policy, &cfg, hb, &tx);
                }));
            }
            // Drain while this wave runs: collect exactly wave-many Done
            // events (plus any progress chatter).
            let mut done_in_wave = 0;
            while done_in_wave < wave.len() {
                match rx.recv() {
                    Ok(WorkerEvent::Progress { .. }) => heartbeats += 1,
                    Ok(WorkerEvent::Done { node, result }) => {
                        let idx = assignments
                            .iter()
                            .position(|a| a.node == node)
                            .expect("known node");
                        results[idx] = Some(result);
                        done_in_wave += 1;
                    }
                    Err(_) => anyhow::bail!("worker channel closed early"),
                }
            }
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
            }
        }
        drop(tx);

        let nodes: Vec<NodeResult> =
            results.into_iter().map(|r| r.expect("all nodes done")).collect();
        let mut total = 0.0;
        let mut saved = 0.0;
        let mut per_app_acc: BTreeMap<String, Welford> = BTreeMap::new();
        for r in &nodes {
            total += r.metrics.gpu_energy_kj;
            let app = calibration::app(&r.app).unwrap();
            saved += app.energy_kj[freqs.max_arm()] - r.metrics.gpu_energy_kj;
            per_app_acc.entry(r.app.clone()).or_default().push(r.metrics.gpu_energy_kj);
        }
        let per_app = per_app_acc
            .into_iter()
            .map(|(k, w)| (k, (w.count(), w.mean(), w.sample_std())))
            .collect();
        Ok(ClusterReport { nodes, total_energy_kj: total, total_saved_kj: saved, heartbeats, per_app })
    }

    fn build_policy_cfg(&self) -> crate::config::ExperimentConfig {
        crate::config::ExperimentConfig {
            policy: self.cfg.policy.clone(),
            ..crate::config::ExperimentConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_round_robin() {
        let a = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 5, 100);
        assert_eq!(a.len(), 5);
        assert_eq!(a[0].app, "tealeaf");
        assert_eq!(a[1].app, "clvleaf");
        assert_eq!(a[4].app, "tealeaf");
        assert_eq!(a[3].seed, 103);
    }

    #[test]
    fn cluster_runs_nodes_in_parallel_and_merges() {
        let cfg = ClusterConfig {
            parallelism: 4,
            heartbeat_steps: 2_000,
            ..ClusterConfig::default()
        };
        let leader = Leader::new(cfg);
        let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 6, 42);
        let report = leader.run(&assignments).unwrap();
        assert_eq!(report.nodes.len(), 6);
        // Deterministic order by node id.
        for (i, r) in report.nodes.iter().enumerate() {
            assert_eq!(r.node, i);
        }
        assert!(report.heartbeats > 0);
        // Energy in calibrated range per app.
        let (n_tea, mean_tea, _) = report.per_app["tealeaf"];
        assert_eq!(n_tea, 3);
        assert!(mean_tea > 95.0 && mean_tea < 108.0, "{mean_tea}");
        // Saved energy positive overall (EnergyUCB on these apps).
        assert!(report.total_saved_kj > 0.0);
    }

    #[test]
    fn cluster_is_deterministic_given_seeds() {
        let mk = || {
            let leader = Leader::new(ClusterConfig {
                parallelism: 2,
                ..ClusterConfig::default()
            });
            let assignments = Leader::assign_round_robin(&["clvleaf"], 4, 7);
            leader.run(&assignments).unwrap().total_energy_kj
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn unknown_app_is_an_error() {
        let leader = Leader::new(ClusterConfig::default());
        let bad = vec![NodeAssignment { node: 0, app: "nope".into(), seed: 1 }];
        assert!(leader.run(&bad).is_err());
    }
}
