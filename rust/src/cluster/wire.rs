//! JSONL wire codec for the sharded cluster runtime.
//!
//! Shard workers are separate OS processes (`energyucb cluster-worker`),
//! so every [`NodeAssignment`] — including its [`PolicyConfig`] and
//! [`SwitchCost`] overrides — and every [`WorkerEvent`] crosses a pipe as
//! one line of JSON. serde is not in the offline crate set, so the codec
//! is hand-rolled on [`crate::util::io::Json`].
//!
//! Round-trips are exact: floats ride Rust's shortest round-trip
//! formatting (`Json::render*` / `Json::parse`), with string sentinels
//! for the values JSON numbers cannot carry (NaN/±inf/-0.0, see
//! [`f64_to_json`]), and integers above 2^53 fall back to decimal
//! strings (see [`u64_to_json`]) — so a decoded shard re-runs its
//! sessions bit-identically and the merged [`ClusterReport`] stays
//! byte-identical across `--shards` (EXPERIMENTS.md §Cluster).
//!
//! Frame grammar (one [`Frame`] per line, leader ⇄ worker):
//!
//! ```text
//! leader → worker stdin:   config  assign*  run
//! worker → leader stdout:  event*  (end | error)
//! ```
//!
//! [`ClusterReport`]: super::ClusterReport

use crate::bandit::energyucb::{EnergyUcbConfig, InitStrategy};
use crate::bandit::RewardForm;
use crate::config::PolicyConfig;
use crate::control::{RunMetrics, SessionCfg};
use crate::sim::freq::{FreqDomain, SwitchCost};
use crate::util::io::Json;
use crate::util::wire::{
    bool_field, err, f64_field, f64s_from_json, f64s_to_json, field, str_field, u64_field,
    usize_field,
};

use super::leader::NodeAssignment;
use super::worker::{NodeResult, WorkerEvent};

// The lossless primitives (float/integer codecs, the `WireCodec` trait
// and `WireError`) live in `util::wire` — shared with the controller's
// telemetry record/replay log — and are re-exported here so existing
// `cluster::wire::*` callers keep working.
pub use crate::util::wire::{
    f64_from_json, f64_to_json, u64_from_json, u64_to_json, WireCodec, WireError,
};

impl WireCodec for SwitchCost {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("latency_s", f64_to_json(self.latency_s));
        j.set("energy_j", f64_to_json(self.energy_j));
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let cost = SwitchCost {
            latency_s: f64_field(v, "latency_s")?,
            energy_j: f64_field(v, "energy_j")?,
        };
        // `!(x >= 0)` also rejects NaN: a tampered frame must not smuggle
        // negative per-transition time/energy into the simulator.
        if !(cost.latency_s >= 0.0 && cost.energy_j >= 0.0) {
            return err("switch cost must be non-negative and finite");
        }
        Ok(cost)
    }
}

/// Decode a `freqs_ghz` arm-set array into a validated domain. The
/// domain crosses the wire as the bare GHz list only — its embedded
/// switch cost is deliberately NOT carried, because `SessionCfg::domain`
/// always overrides it with the top-level `switch_cost` field; one
/// on-wire source of truth per value.
fn freq_domain_from_json(v: &Json) -> Result<FreqDomain, WireError> {
    let ghz = f64s_from_json(v).map_err(|e| WireError(format!("freqs_ghz: {}", e.0)))?;
    FreqDomain::try_new(ghz).map_err(|e| WireError(format!("invalid frequency domain: {e}")))
}

impl WireCodec for EnergyUcbConfig {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("alpha", f64_to_json(self.alpha));
        j.set("lambda", f64_to_json(self.lambda));
        j.set("mu_init", f64_to_json(self.mu_init));
        j.set("prior_n", f64_to_json(self.prior_n));
        j.set(
            "init",
            match self.init {
                InitStrategy::Optimistic => "optimistic",
                InitStrategy::WarmupRoundRobin => "warmup",
            },
        );
        j.set("discount", f64_to_json(self.discount));
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let init = match str_field(v, "init")?.as_str() {
            "optimistic" => InitStrategy::Optimistic,
            "warmup" => InitStrategy::WarmupRoundRobin,
            other => return err(format!("unknown init strategy: {other}")),
        };
        Ok(EnergyUcbConfig {
            alpha: f64_field(v, "alpha")?,
            lambda: f64_field(v, "lambda")?,
            mu_init: f64_field(v, "mu_init")?,
            prior_n: f64_field(v, "prior_n")?,
            init,
            discount: f64_field(v, "discount")?,
        })
    }
}

impl WireCodec for PolicyConfig {
    /// Tagged by the same `name` strings the `[policy]` config surface
    /// uses, so wire dumps read like config files.
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        match self {
            PolicyConfig::EnergyUcb(c) => {
                j.set("name", "energyucb");
                j.set("ucb", c.to_wire());
            }
            PolicyConfig::ConstrainedEnergyUcb { ucb, delta } => {
                j.set("name", "constrained");
                j.set("ucb", ucb.to_wire());
                j.set("delta", f64_to_json(*delta));
            }
            PolicyConfig::Ucb1 { alpha } => {
                j.set("name", "ucb1");
                j.set("alpha", f64_to_json(*alpha));
            }
            PolicyConfig::SwUcb { alpha, lambda, window } => {
                j.set("name", "swucb");
                j.set("alpha", f64_to_json(*alpha));
                j.set("lambda", f64_to_json(*lambda));
                j.set("window", *window);
            }
            PolicyConfig::EpsilonGreedy { eps0, decay_c } => {
                j.set("name", "egreedy");
                j.set("eps0", f64_to_json(*eps0));
                j.set("decay_c", f64_to_json(*decay_c));
            }
            PolicyConfig::EnergyTs => {
                j.set("name", "energyts");
            }
            PolicyConfig::RoundRobin => {
                j.set("name", "rrfreq");
            }
            PolicyConfig::Static { arm } => {
                j.set("name", "static");
                j.set("arm", *arm);
            }
            PolicyConfig::RlPower => {
                j.set("name", "rlpower");
            }
            PolicyConfig::DrlCap { mode } => {
                j.set("name", "drlcap");
                j.set("mode", mode.as_str());
            }
            PolicyConfig::PanicAfter { after } => {
                j.set("name", "panicafter");
                j.set("after", u64_to_json(*after));
            }
            PolicyConfig::LinUcb { alpha, ridge } => {
                j.set("name", "linucb");
                j.set("alpha", f64_to_json(*alpha));
                j.set("ridge", f64_to_json(*ridge));
            }
            PolicyConfig::CLinUcb { alpha, ridge, delta } => {
                j.set("name", "clinucb");
                j.set("alpha", f64_to_json(*alpha));
                j.set("ridge", f64_to_json(*ridge));
                j.set("delta", f64_to_json(*delta));
            }
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(match str_field(v, "name")?.as_str() {
            "energyucb" => PolicyConfig::EnergyUcb(EnergyUcbConfig::from_wire(field(v, "ucb")?)?),
            "constrained" => PolicyConfig::ConstrainedEnergyUcb {
                ucb: EnergyUcbConfig::from_wire(field(v, "ucb")?)?,
                delta: f64_field(v, "delta")?,
            },
            "ucb1" => PolicyConfig::Ucb1 { alpha: f64_field(v, "alpha")? },
            "swucb" => PolicyConfig::SwUcb {
                alpha: f64_field(v, "alpha")?,
                lambda: f64_field(v, "lambda")?,
                window: usize_field(v, "window")?,
            },
            "egreedy" => PolicyConfig::EpsilonGreedy {
                eps0: f64_field(v, "eps0")?,
                decay_c: f64_field(v, "decay_c")?,
            },
            "energyts" => PolicyConfig::EnergyTs,
            "rrfreq" => PolicyConfig::RoundRobin,
            "static" => PolicyConfig::Static { arm: usize_field(v, "arm")? },
            "rlpower" => PolicyConfig::RlPower,
            "drlcap" => PolicyConfig::DrlCap { mode: str_field(v, "mode")? },
            "panicafter" => PolicyConfig::PanicAfter { after: u64_field(v, "after")? },
            "linucb" => PolicyConfig::LinUcb {
                alpha: f64_field(v, "alpha")?,
                ridge: f64_field(v, "ridge")?,
            },
            "clinucb" => PolicyConfig::CLinUcb {
                alpha: f64_field(v, "alpha")?,
                ridge: f64_field(v, "ridge")?,
                delta: f64_field(v, "delta")?,
            },
            other => return err(format!("unknown policy: {other}")),
        })
    }
}

impl WireCodec for RewardForm {
    fn to_wire(&self) -> Json {
        Json::Str(self.name().to_string())
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        match v.as_str() {
            Some("E*R") => Ok(RewardForm::EnergyRatio),
            Some("E^2*R") => Ok(RewardForm::EnergySquaredRatio),
            Some("E*R^2") => Ok(RewardForm::EnergyRatioSquared),
            Some(other) => err(format!("unknown reward form: {other}")),
            None => err("reward form must be a string"),
        }
    }
}

impl WireCodec for SessionCfg {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("dt_s", f64_to_json(self.dt_s));
        j.set("seed", u64_to_json(self.seed));
        j.set("record_trace", self.record_trace);
        j.set("max_steps", u64_to_json(self.max_steps));
        j.set("reward_form", self.reward_form.to_wire());
        j.set("checkpoints", self.checkpoints);
        j.set("freqs_ghz", f64s_to_json(self.freqs.ghz_all()));
        j.set("switch_cost", self.switch_cost.to_wire());
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(SessionCfg {
            dt_s: f64_field(v, "dt_s")?,
            seed: u64_field(v, "seed")?,
            record_trace: bool_field(v, "record_trace")?,
            max_steps: u64_field(v, "max_steps")?,
            reward_form: RewardForm::from_wire(field(v, "reward_form")?)?,
            checkpoints: usize_field(v, "checkpoints")?,
            freqs: freq_domain_from_json(field(v, "freqs_ghz")?)?,
            switch_cost: SwitchCost::from_wire(field(v, "switch_cost")?)?,
        })
    }
}

impl WireCodec for NodeAssignment {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("node", self.node);
        j.set("app", self.app.as_str());
        j.set("seed", u64_to_json(self.seed));
        j.set(
            "max_steps",
            match self.max_steps {
                Some(m) => u64_to_json(m),
                None => Json::Null,
            },
        );
        j.set(
            "policy",
            match &self.policy {
                Some(p) => p.to_wire(),
                None => Json::Null,
            },
        );
        j.set(
            "switch_cost",
            match &self.switch_cost {
                Some(c) => c.to_wire(),
                None => Json::Null,
            },
        );
        j.set(
            "freqs_ghz",
            match &self.freqs_ghz {
                Some(ghz) => f64s_to_json(ghz),
                None => Json::Null,
            },
        );
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let max_steps = match field(v, "max_steps")? {
            Json::Null => None,
            x => Some(u64_from_json(x).map_err(|e| WireError(format!("max_steps: {}", e.0)))?),
        };
        let policy = match field(v, "policy")? {
            Json::Null => None,
            x => Some(PolicyConfig::from_wire(x)?),
        };
        let switch_cost = match field(v, "switch_cost")? {
            Json::Null => None,
            x => Some(SwitchCost::from_wire(x)?),
        };
        let freqs_ghz = match field(v, "freqs_ghz")? {
            Json::Null => None,
            x => Some(
                f64s_from_json(x).map_err(|e| WireError(format!("freqs_ghz: {}", e.0)))?,
            ),
        };
        Ok(NodeAssignment {
            node: usize_field(v, "node")?,
            app: str_field(v, "app")?,
            seed: u64_field(v, "seed")?,
            max_steps,
            policy,
            switch_cost,
            freqs_ghz,
        })
    }
}

impl WireCodec for RunMetrics {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("app", self.app.as_str());
        j.set("policy", self.policy.as_str());
        j.set("gpu_energy_kj", f64_to_json(self.gpu_energy_kj));
        j.set("exec_time_s", f64_to_json(self.exec_time_s));
        j.set("switches", u64_to_json(self.switches));
        j.set("switch_energy_j", f64_to_json(self.switch_energy_j));
        j.set("switch_time_s", f64_to_json(self.switch_time_s));
        j.set("cumulative_regret", f64_to_json(self.cumulative_regret));
        j.set("steps", u64_to_json(self.steps));
        j.set("completed", f64_to_json(self.completed));
        // Written only when populated, so context-free shard streams
        // stay byte-identical to the pre-QoS grammar.
        if let Some(q) = self.qos_violation_frac {
            j.set("qos_violation_frac", f64_to_json(q));
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let qos_violation_frac = match v.get("qos_violation_frac") {
            None => None,
            Some(x) => Some(
                f64_from_json(x)
                    .map_err(|e| WireError(format!("qos_violation_frac: {}", e.0)))?,
            ),
        };
        Ok(RunMetrics {
            app: str_field(v, "app")?,
            policy: str_field(v, "policy")?,
            gpu_energy_kj: f64_field(v, "gpu_energy_kj")?,
            exec_time_s: f64_field(v, "exec_time_s")?,
            switches: u64_field(v, "switches")?,
            switch_energy_j: f64_field(v, "switch_energy_j")?,
            switch_time_s: f64_field(v, "switch_time_s")?,
            cumulative_regret: f64_field(v, "cumulative_regret")?,
            steps: u64_field(v, "steps")?,
            completed: f64_field(v, "completed")?,
            qos_violation_frac,
        })
    }
}

impl WireCodec for NodeResult {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("node", self.node);
        j.set("app", self.app.as_str());
        j.set("metrics", self.metrics.to_wire());
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(NodeResult {
            node: usize_field(v, "node")?,
            app: str_field(v, "app")?,
            metrics: RunMetrics::from_wire(field(v, "metrics")?)?,
        })
    }
}

impl WireCodec for WorkerEvent {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        match self {
            WorkerEvent::Progress { node, completed, energy_j } => {
                j.set("event", "progress");
                j.set("node", *node);
                j.set("completed", f64_to_json(*completed));
                j.set("energy_j", f64_to_json(*energy_j));
            }
            WorkerEvent::Done { node, result } => {
                j.set("event", "done");
                j.set("node", *node);
                j.set("result", result.to_wire());
            }
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(match str_field(v, "event")?.as_str() {
            "progress" => WorkerEvent::Progress {
                node: usize_field(v, "node")?,
                completed: f64_field(v, "completed")?,
                energy_j: f64_field(v, "energy_j")?,
            },
            "done" => WorkerEvent::Done {
                node: usize_field(v, "node")?,
                result: NodeResult::from_wire(field(v, "result")?)?,
            },
            other => return err(format!("unknown event kind: {other}")),
        })
    }
}

/// One line of the leader ⇄ worker protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Shard runtime configuration; must precede `run`.
    Config {
        jobs: usize,
        heartbeat_steps: u64,
        policy: PolicyConfig,
        session: SessionCfg,
    },
    /// One node assignment of the shard's batch.
    Assign(NodeAssignment),
    /// End of batch: execute the shard.
    Run,
    /// One worker telemetry/result event.
    Event(WorkerEvent),
    /// Terminal success: the worker emitted `nodes` Done events
    /// (stream-integrity check on the leader).
    End { nodes: usize },
    /// Terminal failure with a human-readable reason.
    Error { message: String },
}

impl Frame {
    /// Encode as one JSONL line (no trailing newline).
    pub fn encode_line(&self) -> String {
        self.to_wire().render_compact()
    }

    /// Decode one JSONL line.
    pub fn decode_line(line: &str) -> Result<Frame, WireError> {
        let v = Json::parse(line).map_err(|e| WireError(e.to_string()))?;
        Frame::from_wire(&v)
    }
}

impl WireCodec for Frame {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        match self {
            Frame::Config { jobs, heartbeat_steps, policy, session } => {
                j.set("frame", "config");
                j.set("jobs", *jobs);
                j.set("heartbeat_steps", u64_to_json(*heartbeat_steps));
                j.set("policy", policy.to_wire());
                j.set("session", session.to_wire());
            }
            Frame::Assign(a) => {
                j.set("frame", "assign");
                j.set("assignment", a.to_wire());
            }
            Frame::Run => {
                j.set("frame", "run");
            }
            Frame::Event(ev) => {
                j.set("frame", "event");
                j.set("payload", ev.to_wire());
            }
            Frame::End { nodes } => {
                j.set("frame", "end");
                j.set("nodes", *nodes);
            }
            Frame::Error { message } => {
                j.set("frame", "error");
                j.set("message", message.as_str());
            }
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(match str_field(v, "frame")?.as_str() {
            "config" => Frame::Config {
                jobs: usize_field(v, "jobs")?,
                heartbeat_steps: u64_field(v, "heartbeat_steps")?,
                policy: PolicyConfig::from_wire(field(v, "policy")?)?,
                session: SessionCfg::from_wire(field(v, "session")?)?,
            },
            "assign" => Frame::Assign(NodeAssignment::from_wire(field(v, "assignment")?)?),
            "run" => Frame::Run,
            "event" => Frame::Event(WorkerEvent::from_wire(field(v, "payload")?)?),
            "end" => Frame::End { nodes: usize_field(v, "nodes")? },
            "error" => Frame::Error { message: str_field(v, "message")? },
            other => return err(format!("unknown frame type: {other}")),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_with_overrides_round_trips() {
        let a = NodeAssignment {
            node: 17,
            app: "tealeaf".into(),
            seed: u64::MAX - 3, // exercises the >2^53 string path
            max_steps: Some(1_500),
            policy: Some(PolicyConfig::ConstrainedEnergyUcb {
                ucb: EnergyUcbConfig::default(),
                delta: 0.05,
            }),
            switch_cost: Some(SwitchCost { latency_s: 450e-6, energy_j: 0.9 }),
            freqs_ghz: Some((8..=16).map(|i| i as f64 / 10.0).collect()),
        };
        let line = Frame::Assign(a.clone()).encode_line();
        assert!(!line.contains('\n'), "{line}");
        assert_eq!(Frame::decode_line(&line).unwrap(), Frame::Assign(a));
    }

    #[test]
    fn bare_assignment_keeps_nulls() {
        let a = NodeAssignment::new(0, "clvleaf", 7);
        let j = a.to_wire();
        assert!(j.get("max_steps").unwrap().is_null());
        assert!(j.get("policy").unwrap().is_null());
        assert!(j.get("freqs_ghz").unwrap().is_null());
        assert_eq!(NodeAssignment::from_wire(&j).unwrap(), a);
    }

    #[test]
    fn freq_domain_and_switch_cost_decode_paths_validate() {
        // Malformed domains are wire errors, not panics.
        for bad in ["[]", "[1.0,0.9]", "[-1.0]", "[\"a\"]", "1.0"] {
            let v = Json::parse(bad).unwrap();
            assert!(freq_domain_from_json(&v).is_err(), "{bad}");
        }
        let ok = freq_domain_from_json(&Json::parse("[0.9,1.2,1.5]").unwrap()).unwrap();
        assert_eq!(ok, FreqDomain::new(vec![0.9, 1.2, 1.5]));
        // The cost validation lives on SwitchCost's own codec — the path
        // SessionCfg / NodeAssignment overrides decode through.
        for bad in [
            "{\"latency_s\":-1,\"energy_j\":0}",
            "{\"latency_s\":0,\"energy_j\":-5}",
            "{\"latency_s\":\"nan\",\"energy_j\":0}",
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(SwitchCost::from_wire(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn session_cfg_carries_the_frequency_domain() {
        let cfg = SessionCfg {
            freqs: FreqDomain::new(vec![0.5, 0.7, 0.9]),
            ..SessionCfg::default()
        };
        let j = cfg.to_wire();
        assert_eq!(SessionCfg::from_wire(&j).unwrap(), cfg);
    }

    #[test]
    fn every_policy_kind_round_trips() {
        let policies = [
            PolicyConfig::EnergyUcb(EnergyUcbConfig::default()),
            PolicyConfig::ConstrainedEnergyUcb { ucb: EnergyUcbConfig::default(), delta: 0.1 },
            PolicyConfig::Ucb1 { alpha: 0.05 },
            PolicyConfig::EpsilonGreedy { eps0: 0.1, decay_c: 20.0 },
            PolicyConfig::EnergyTs,
            PolicyConfig::RoundRobin,
            PolicyConfig::Static { arm: 7 },
            PolicyConfig::RlPower,
            PolicyConfig::DrlCap { mode: "cross".into() },
            PolicyConfig::PanicAfter { after: 42 },
            PolicyConfig::LinUcb { alpha: 0.4, ridge: 1.0 },
            PolicyConfig::CLinUcb { alpha: 0.4, ridge: 2.0, delta: 0.05 },
        ];
        for p in policies {
            let j = p.to_wire();
            assert_eq!(PolicyConfig::from_wire(&j).unwrap(), p, "{j:?}");
        }
    }

    #[test]
    fn config_frame_round_trips() {
        let f = Frame::Config {
            jobs: 4,
            heartbeat_steps: 500,
            policy: PolicyConfig::Static { arm: 8 },
            session: SessionCfg { seed: 99, max_steps: 400, ..SessionCfg::default() },
        };
        assert_eq!(Frame::decode_line(&f.encode_line()).unwrap(), f);
    }

    // The f64/u64 primitive codec tests live with the primitives in
    // `util::wire`.

    #[test]
    fn decode_rejects_malformed_frames() {
        for bad in [
            "",
            "{\"frame\":\"assign\"}",
            "{\"frame\":\"bogus\"}",
            "{\"frame\":\"end\",\"nodes\":-1}",
            "{\"frame\":\"end\",\"nodes\":1.5}",
            "[\"frame\",\"run\"]",
            "{\"frame\":\"run\"} trailing",
        ] {
            assert!(Frame::decode_line(bad).is_err(), "{bad:?}");
        }
    }
}
