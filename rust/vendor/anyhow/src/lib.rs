//! Minimal offline drop-in for the `anyhow` error crate.
//!
//! The build environment has no registry access and the real `anyhow` is
//! not part of the vendored xla closure, so this shim provides the API
//! subset the workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` macros. Error chains are
//! captured eagerly as strings (no downcasting), which is all the
//! experiment harness needs; `{err:#}` prints the full chain, `{err}` the
//! outermost message, matching the real crate's formatting contract.

use std::error::Error as StdError;
use std::fmt;

/// A string-chained error value. Outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real crate, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent (and lets `?` convert any std error into `Error`).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing file");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        let e = f(0).unwrap_err();
        assert_eq!(format!("{e}"), "zero not allowed (got 0)");
        let e2 = anyhow!("code {}", 7);
        assert_eq!(format!("{e2}"), "code 7");
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = Error::from(io_err()).context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "missing file"]);
    }
}
