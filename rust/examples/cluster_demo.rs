//! Multi-node orchestration demo: the cluster leader runs EnergyUCB on a
//! rack of simulated Aurora nodes in parallel worker threads, streams
//! telemetry, and merges per-node results — the production-deployment
//! shape behind the paper's fleet-scale impact claim.
//!
//! ```sh
//! cargo run --release --example cluster_demo [nodes] [parallelism]
//! ```

use energyucb::cluster::{ClusterConfig, Leader};
use energyucb::util::table::{fnum, fnum_sep, Table};
use energyucb::workload::calibration::APP_NAMES;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let nodes: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(18);
    let parallelism: usize = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));

    // Short/medium apps for a snappy demo (the long LLM runs are covered
    // by `energyucb exp impact`).
    let apps = ["lbm", "tealeaf", "clvleaf", "miniswp", "pot3d", "weather"];
    println!(
        "cluster demo: {nodes} nodes x EnergyUCB over {:?} ({parallelism} workers)\n",
        apps
    );

    let leader = Leader::new(ClusterConfig { parallelism, ..ClusterConfig::default() });
    let assignments = Leader::assign_round_robin(&apps, nodes, 2026);
    let t0 = std::time::Instant::now();
    let report = leader.run(&assignments)?;
    let wall = t0.elapsed();

    let mut table = Table::new(vec!["app", "nodes", "mean kJ", "std kJ"]);
    for (app, (count, mean, std)) in &report.per_app {
        table.row(vec![
            app.clone(),
            count.to_string(),
            fnum_sep(*mean, 2),
            fnum(*std, 2),
        ]);
    }
    println!("{}", table.render());
    println!(
        "total GPU energy {} kJ, saved vs 1.6 GHz defaults {} kJ \
         ({} telemetry heartbeats, {:.1}s wall)",
        fnum_sep(report.total_energy_kj, 1),
        fnum_sep(report.total_saved_kj, 1),
        report.heartbeats,
        wall.as_secs_f64()
    );
    let sim_seconds: f64 = report.nodes.iter().map(|n| n.metrics.exec_time_s).sum();
    println!(
        "simulated {:.0} node-seconds of the rack in {:.1}s ({:.0}x real time)",
        sim_seconds,
        wall.as_secs_f64(),
        sim_seconds / wall.as_secs_f64()
    );
    let _ = APP_NAMES; // full suite available via --nodes over all 9 apps
    Ok(())
}
