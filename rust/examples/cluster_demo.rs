//! Multi-node orchestration demo: the cluster leader runs a scenario
//! schedule over a rack of simulated Aurora nodes on the work-stealing
//! executor, streams telemetry, and merges per-node results — the
//! production-deployment shape behind the paper's fleet-scale impact
//! claim. (The CLI equivalent is `energyucb cluster`.)
//!
//! ```sh
//! cargo run --release --example cluster_demo [nodes] [jobs] [scenario]
//! ```

use energyucb::cluster::{ClusterConfig, Leader, ScenarioSchedule};
use energyucb::exec::available_jobs;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let nodes: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(18);
    let jobs: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or_else(available_jobs);
    let scenario = args.next().unwrap_or_else(|| "mixed".to_string());

    let schedule = ScenarioSchedule::preset(&scenario, 2026)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario: {scenario}"))?;
    println!("cluster demo: {nodes} nodes, scenario {scenario} ({jobs} jobs)\n");

    let leader = Leader::new(ClusterConfig { jobs, ..ClusterConfig::default() });
    let assignments = schedule.assignments(nodes).map_err(|e| anyhow::anyhow!(e))?;
    let t0 = std::time::Instant::now();
    let report = leader.run(&assignments)?;
    let wall = t0.elapsed();

    print!("{}", report.render());
    let sim_seconds: f64 = report.nodes.iter().map(|n| n.metrics.exec_time_s).sum();
    println!(
        "wall {:.1}s — simulated {:.0} node-seconds of the rack ({:.0}x real time)",
        wall.as_secs_f64(),
        sim_seconds,
        sim_seconds / wall.as_secs_f64().max(1e-9)
    );

    // The same scenario under the legacy fixed-wave scheduler: identical
    // report, slower wall-clock on mixed-duration scenarios.
    let t0 = std::time::Instant::now();
    let wave_report = leader.run_waves(&assignments)?;
    let wave_wall = t0.elapsed();
    assert_eq!(wave_report.render(), report.render(), "schedulers must agree");
    println!(
        "wave-scheduler reference: {:.1}s wall ({:.2}x the stealing pool)",
        wave_wall.as_secs_f64(),
        wave_wall.as_secs_f64() / wall.as_secs_f64().max(1e-9)
    );
    Ok(())
}
