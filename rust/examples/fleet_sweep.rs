//! Fleet-scale Monte Carlo through the AOT pipeline: load the compiled
//! `fleet_step` HLO artifact, run hundreds of seeded environments in
//! lockstep on the PJRT CPU client, and cross-check the native engine —
//! the end-to-end proof that L1 (Pallas) → L2 (JAX) → HLO text → L3 (rust)
//! compose. Falls back to the native engine if artifacts are missing.
//!
//! ```sh
//! make artifacts && cargo run --release --example fleet_sweep [batch] [steps]
//! ```

use std::path::Path;

use energyucb::fleet::{native, FleetEngine, FleetHyper, FleetParams, FleetState};
use energyucb::runtime::XlaRuntime;
use energyucb::sim::freq::FreqDomain;
use energyucb::util::stats::Summary;
use energyucb::util::table::{fnum, Table};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let batch: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(256);
    let steps: u64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(6_000);
    let seed = 2026;

    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    let params = FleetParams::from_apps(&assigned, &freqs, 0.01);
    let hyper = FleetHyper::default();

    // HLO engine (if exported for this batch size).
    let art = Path::new("artifacts");
    let hlo_available = art.join(format!("fleet_step_b{batch}.hlo.txt")).exists();

    let mut hlo_state = FleetState::fresh(batch, freqs.k());
    let mut hlo_wall = None;
    if hlo_available {
        let runtime = XlaRuntime::cpu()?;
        println!("PJRT platform: {} ({} devices)", runtime.platform_name(), runtime.device_count());
        let engine = FleetEngine::load(&runtime, art, params.clone(), hyper)?;
        let mut rng = Rng::new(seed);
        let t0 = std::time::Instant::now();
        engine.run(&mut hlo_state, &mut rng, steps)?;
        hlo_wall = Some(t0.elapsed());
    } else {
        eprintln!("artifacts/fleet_step_b{batch}.hlo.txt missing — run `make artifacts` (native only)");
    }

    // Native engine, identical noise stream.
    let mut nat_state = FleetState::fresh(batch, freqs.k());
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    native::native_run(&mut nat_state, &params, &hyper, &mut rng, steps);
    let nat_wall = t0.elapsed();

    println!("\nfleet sweep: B={batch}, {steps} steps, {} apps cycled", apps.len());
    let mut table = Table::new(vec!["engine", "wall s", "env-steps/s", "mean cum kJ", "mean regret"]);
    let mean_kj = |s: &FleetState| {
        s.cum_energy.iter().map(|e| *e as f64 / 1000.0).sum::<f64>() / batch as f64
    };
    let mean_reg =
        |s: &FleetState| s.cum_regret.iter().map(|r| *r as f64).sum::<f64>() / batch as f64;
    if let Some(w) = hlo_wall {
        table.row(vec![
            "hlo (PJRT)".to_string(),
            fnum(w.as_secs_f64(), 2),
            fnum(batch as f64 * steps as f64 / w.as_secs_f64(), 0),
            fnum(mean_kj(&hlo_state), 2),
            fnum(mean_reg(&hlo_state), 1),
        ]);
    }
    table.row(vec![
        "native".to_string(),
        fnum(nat_wall.as_secs_f64(), 2),
        fnum(batch as f64 * steps as f64 / nat_wall.as_secs_f64(), 0),
        fnum(mean_kj(&nat_state), 2),
        fnum(mean_reg(&nat_state), 1),
    ]);
    println!("{}", table.render());

    if hlo_available {
        // Cross-check.
        let diffs: Vec<f64> = (0..batch)
            .map(|e| {
                let a = hlo_state.cum_energy[e] as f64;
                let b = nat_state.cum_energy[e] as f64;
                (a - b).abs() / b.max(1.0)
            })
            .collect();
        let s = Summary::of(&diffs);
        println!(
            "cross-check |hlo - native| relative energy: mean {:.2e}, p99 {:.2e}, max {:.2e}",
            s.mean, s.p99, s.max
        );
        assert!(s.max < 0.02, "engines diverged");
        println!("engines agree ✓ (three-layer AOT pipeline validated)");
    }

    // Seed-variance summary per app (first occurrence pattern).
    let mut table = Table::new(vec!["app", "seeds", "mean regret", "std"]);
    for (i, app) in apps.iter().enumerate() {
        let regrets: Vec<f64> = (0..batch)
            .filter(|e| e % apps.len() == i)
            .map(|e| nat_state.cum_regret[e] as f64)
            .collect();
        if regrets.len() < 2 {
            continue;
        }
        let s = Summary::of(&regrets);
        table.row(vec![
            app.name.to_string(),
            regrets.len().to_string(),
            fnum(s.mean, 1),
            fnum(s.std, 1),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
