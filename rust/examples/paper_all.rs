//! End-to-end validation driver: regenerate every table and figure of the
//! paper on the calibrated workload suite and print the headline metrics.
//! This is the "one command reproduces the paper" entrypoint
//! (equivalently: `energyucb exp all`).
//!
//! ```sh
//! make artifacts && cargo run --release --example paper_all [--quick]
//! ```

use energyucb::experiments::{all_experiments, ExpContext};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = ExpContext {
        quick,
        reps: if quick { 2 } else { 10 },
        out_dir: std::path::PathBuf::from("results"),
        ..ExpContext::default()
    };
    let t0 = std::time::Instant::now();
    for exp in all_experiments() {
        eprintln!("\n=== {} — {} ===", exp.id(), exp.title());
        let report = exp.run(&ctx)?;
        println!("# {} — {}\n\n{}", exp.id(), exp.title(), report.text);
        report.write(&ctx.out_dir)?;
    }
    eprintln!(
        "\nall experiments done in {:.1}s — results/ has JSON+CSV per experiment",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
