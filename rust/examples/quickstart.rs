//! Quickstart: run EnergyUCB on one calibrated benchmark and print the
//! paper's headline metrics (energy vs the 1.6 GHz default, energy regret
//! vs the best static frequency, switching overhead).
//!
//! ```sh
//! cargo run --release --example quickstart [app] [seed]
//! ```

use energyucb::bandit::{EnergyUcb, EnergyUcbConfig};
use energyucb::control::{run_session, SessionCfg};
use energyucb::sim::freq::FreqDomain;
use energyucb::workload;

fn main() {
    let mut args = std::env::args().skip(1);
    let app_name = args.next().unwrap_or_else(|| "tealeaf".to_string());
    let seed: u64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(2026);

    let app = workload::app(&app_name).unwrap_or_else(|| {
        eprintln!("unknown app {app_name}; known: {:?}", workload::APP_NAMES);
        std::process::exit(2);
    });
    let freqs = FreqDomain::aurora();

    println!("EnergyUCB quickstart — {app_name} on one simulated Aurora node");
    println!(
        "  workload: {:?}, T(1.6 GHz) = {:.1}s, optimal static = {}",
        app.class,
        app.t_max_s,
        freqs.label(app.optimal_arm())
    );

    let mut policy = EnergyUcb::new(freqs.k(), EnergyUcbConfig::default());
    let cfg = SessionCfg { seed, ..SessionCfg::default() };
    let t0 = std::time::Instant::now();
    let result = run_session(&app, &mut policy, &cfg);
    let m = &result.metrics;

    let default_kj = app.energy_kj[freqs.max_arm()];
    println!("\n  decision steps      : {}", m.steps);
    println!("  execution time      : {:.2} s  ({:+.2}% vs 1.6 GHz)", m.exec_time_s, m.slowdown(&app) * 100.0);
    println!("  GPU energy          : {:.2} kJ", m.gpu_energy_kj);
    println!("  default (1.6 GHz)   : {:.2} kJ", default_kj);
    println!("  saved energy        : {:.2} kJ ({:.2}%)", m.saved_energy_kj(&app, &freqs), 100.0 * m.saved_energy_kj(&app, &freqs) / default_kj);
    println!("  energy regret       : {:.2} kJ vs best static {:.2} kJ", m.energy_regret_kj(&app), app.optimal_energy_kj());
    println!("  switches            : {} ({:.2} J, {:.4} s overhead)", m.switches, m.switch_energy_j, m.switch_time_s);
    println!("\n  simulated {:.0}x faster than real time ({:.2} s wall)", m.exec_time_s / t0.elapsed().as_secs_f64(), t0.elapsed().as_secs_f64());

    // Final arm preferences.
    println!("\n  learned preference (pull counts):");
    for i in 0..freqs.k() {
        let n = policy.count(i);
        let bar = "#".repeat((60.0 * n / m.steps as f64).round() as usize);
        println!("    {} {:>7.0} {}", freqs.label(i), n, bar);
    }
}
