//! Extension beyond the paper: non-stationary (phased) workloads and the
//! discounted EnergyUCB variant. A job that switches from compute-bound to
//! memory-bound mid-run moves its energy-optimal frequency; discounting
//! (γ < 1) lets the controller track the drift, while the stationary
//! controller stays stuck on the stale optimum.
//!
//! ```sh
//! cargo run --release --example phased_workload
//! ```

use energyucb::bandit::{EnergyUcb, EnergyUcbConfig, Policy, RewardNormalizer, RewardForm};
use energyucb::sim::freq::FreqDomain;
use energyucb::sim::node::Node;
use energyucb::util::table::{fnum, Table};
use energyucb::workload::calibration;
use energyucb::workload::phase::{Phase, PhasedWorkload};

/// Run a policy over a phased workload by swapping the node's app model at
/// phase boundaries (progress carries across).
fn run_phased(workload: &PhasedWorkload, policy: &mut dyn Policy, seed: u64) -> (f64, f64) {
    let freqs = FreqDomain::aurora();
    let dt = 0.01;
    let mut completed = 0.0f64;
    let mut energy_kj = 0.0;
    let mut time_s = 0.0;
    let mut t = 0u64;
    let mut normalizer = RewardNormalizer::new();
    let mut phase_idx = usize::MAX;
    let mut node: Option<Node> = None;
    let mut consumed_in_phase = 0.0;
    while completed < 1.0 - 1e-9 && t < 2_000_000 {
        let (idx, phase) = workload.phase_at(completed);
        if idx != phase_idx {
            // Enter the new phase: fresh node on this phase's model, sized
            // to the phase's share of work.
            if let Some(n) = node.take() {
                let tot = n.totals();
                energy_kj += tot.gpu_energy_kj;
                time_s += tot.exec_time_s;
            }
            node = Some(Node::new(phase.model.clone(), freqs.clone(), dt, seed + idx as u64));
            phase_idx = idx;
            consumed_in_phase = 0.0;
        }
        let node_ref = node.as_mut().unwrap();
        t += 1;
        let arm = policy.select(t);
        let obs = node_ref.step(arm);
        let raw = RewardForm::EnergyRatio.raw(obs.gpu_energy_j, obs.core_util, obs.uncore_util);
        // The normalizer owns the winsorize clamp (same rule as the session tier).
        policy.update(arm, normalizer.normalize(raw), obs.progress);
        // Node-internal progress is the fraction of the *phase model's*
        // total work; convert to phase-weighted global progress.
        consumed_in_phase += obs.progress;
        completed = (phase_idx as f64).min(1.0) * 0.0
            + workload.phases()[..phase_idx].iter().map(|p| p.weight).sum::<f64>()
            + (consumed_in_phase.min(1.0)) * phase.weight;
        if obs.done {
            completed = workload.phases()[..=phase_idx].iter().map(|p| p.weight).sum();
        }
    }
    if let Some(n) = node.take() {
        let tot = n.totals();
        energy_kj += tot.gpu_energy_kj;
        time_s += tot.exec_time_s;
    }
    (energy_kj, time_s)
}

fn main() {
    let lbm = calibration::app("lbm").unwrap(); // compute-bound: opt 1.5 GHz
    let miniswp = calibration::app("miniswp").unwrap(); // memory-bound: opt 0.8 GHz
    let workload = PhasedWorkload::new(
        "lbm -> miniswp",
        vec![
            Phase { model: lbm, weight: 0.5 },
            Phase { model: miniswp, weight: 0.5 },
        ],
    );

    println!("phased workload: {} (optimum shifts 1.5 GHz -> 0.8 GHz mid-run)\n", "lbm -> miniswp");
    let mut table = Table::new(vec!["controller", "energy kJ", "time s"]);
    let configs = [
        ("EnergyUCB (stationary)", EnergyUcbConfig::default()),
        (
            "EnergyUCB γ=0.999 (discounted)",
            EnergyUcbConfig { discount: 0.999, alpha: 0.06, ..EnergyUcbConfig::default() },
        ),
    ];
    let mut results = Vec::new();
    for (label, cfg) in configs {
        let mut kj_sum = 0.0;
        let mut t_sum = 0.0;
        let reps = 5;
        for rep in 0..reps {
            let mut policy = EnergyUcb::new(9, cfg);
            let (kj, t) = run_phased(&workload, &mut policy, 100 + rep);
            kj_sum += kj;
            t_sum += t;
        }
        table.row(vec![
            label.to_string(),
            fnum(kj_sum / reps as f64, 2),
            fnum(t_sum / reps as f64, 2),
        ]);
        results.push(kj_sum / reps as f64);
    }
    println!("{}", table.render());
    let delta = results[0] - results[1];
    println!(
        "discounting saves {:.2} kJ on the phase shift ({})",
        delta,
        if delta > 0.0 { "tracks the moving optimum ✓" } else { "no benefit at this drift rate" }
    );
}
