//! QoS budget sweep: run Constrained EnergyUCB across a range of slowdown
//! budgets δ and chart the energy/performance frontier (paper §3.3/§4.6,
//! extended beyond the single δ=0.05 point the paper reports).
//!
//! ```sh
//! cargo run --release --example qos_budget [app]
//! ```

use energyucb::bandit::{ConstrainedEnergyUcb, EnergyUcb, EnergyUcbConfig, Policy};
use energyucb::control::{run_repeated, RepeatedMetrics, SessionCfg};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::table::{fnum, Table};
use energyucb::workload;

fn main() {
    let app_name = std::env::args().nth(1).unwrap_or_else(|| "clvleaf".to_string());
    let app = workload::app(&app_name).unwrap_or_else(|| {
        eprintln!("unknown app {app_name}; known: {:?}", workload::APP_NAMES);
        std::process::exit(2);
    });
    let freqs = FreqDomain::aurora();
    let reps = 5;
    let seed = 2026;
    let default_kj = app.energy_kj[freqs.max_arm()];

    println!("QoS frontier for {app_name}: energy vs slowdown budget δ\n");
    let mut table = Table::new(vec![
        "δ budget",
        "energy kJ",
        "saved %",
        "slowdown %",
        "budget kept?",
    ]);

    let mut run = |label: String, policy: &mut dyn Policy, delta: Option<f64>| {
        let results = run_repeated(&app, policy, &SessionCfg::default(), reps, seed);
        let agg = RepeatedMetrics::from_runs(
            &results.iter().map(|r| r.metrics.clone()).collect::<Vec<_>>(),
        );
        let slowdown = agg.time_mean_s / app.t_max_s - 1.0;
        let kept = match delta {
            // Small estimation margin on the noisy progress signal.
            Some(d) => {
                if slowdown <= d + 0.015 {
                    "yes"
                } else {
                    "NO"
                }
            }
            None => "-",
        };
        table.row(vec![
            label,
            fnum(agg.energy_mean_kj, 2),
            fnum(100.0 * (default_kj - agg.energy_mean_kj) / default_kj, 2),
            fnum(slowdown * 100.0, 2),
            kept.to_string(),
        ]);
    };

    for delta in [0.0, 0.02, 0.05, 0.10, 0.20, 0.40] {
        let mut policy = ConstrainedEnergyUcb::new(freqs.k(), EnergyUcbConfig::default(), delta);
        run(format!("δ = {delta:.2}"), &mut policy, Some(delta));
    }
    let mut unconstrained = EnergyUcb::new(freqs.k(), EnergyUcbConfig::default());
    run("unconstrained".to_string(), &mut unconstrained, None);

    println!("{}", table.render());
    println!(
        "Tighter budgets trade energy for performance; δ≥the unconstrained \
         slowdown recovers the unconstrained optimum."
    );
}
