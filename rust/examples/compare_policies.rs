//! Compare every controller (bandits + RL baselines + oracle) on one
//! benchmark: energy, regret, slowdown, switching — a one-app slice of the
//! paper's Table 1 with extra detail.
//!
//! ```sh
//! cargo run --release --example compare_policies [app] [reps]
//! ```

use energyucb::bandit::{
    ConstrainedEnergyUcb, EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Oracle, Policy,
    RoundRobin, StaticPolicy, Ucb1,
};
use energyucb::control::{run_repeated, RepeatedMetrics, SessionCfg};
use energyucb::rl::{DrlCap, DrlCapMode, RlPower};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::table::{fnum, fnum_sep, Table};
use energyucb::workload;

fn main() {
    let mut args = std::env::args().skip(1);
    let app_name = args.next().unwrap_or_else(|| "miniswp".to_string());
    let reps: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(5);
    let seed = 2026;

    let app = workload::app(&app_name).unwrap_or_else(|| {
        eprintln!("unknown app {app_name}; known: {:?}", workload::APP_NAMES);
        std::process::exit(2);
    });
    let freqs = FreqDomain::aurora();
    let k = freqs.k();

    let policies: Vec<Box<dyn Policy>> = vec![
        Box::new(StaticPolicy::labeled(k, freqs.max_arm(), "1.6 GHz (default)")),
        Box::new(Oracle::for_app(&app)),
        Box::new(RoundRobin::new(k)),
        Box::new(EpsilonGreedy::new(k, 0.05, 0.0, seed)),
        Box::new(EnergyTs::default_for(k, seed)),
        Box::new(Ucb1::new(k, 0.04)),
        Box::new(RlPower::new(k, seed)),
        Box::new(DrlCap::new(k, DrlCapMode::Online, seed)),
        Box::new(EnergyUcb::new(k, EnergyUcbConfig::default())),
        Box::new(ConstrainedEnergyUcb::new(k, EnergyUcbConfig::default(), 0.05)),
    ];

    println!(
        "comparing {} policies on {app_name} ({reps} reps, seed {seed})\n",
        policies.len()
    );
    let mut table = Table::new(vec![
        "policy",
        "energy kJ (±std)",
        "vs default",
        "regret kJ",
        "slowdown %",
        "switches",
    ]);
    let default_kj = app.energy_kj[freqs.max_arm()];
    for mut policy in policies {
        let results = run_repeated(&app, policy.as_mut(), &SessionCfg::default(), reps, seed);
        let agg = RepeatedMetrics::from_runs(
            &results.iter().map(|r| r.metrics.clone()).collect::<Vec<_>>(),
        );
        table.row(vec![
            policy.name(),
            format!("{} ± {:.2}", fnum_sep(agg.energy_mean_kj, 2), agg.energy_std_kj),
            format!("{:+.2}%", 100.0 * (agg.energy_mean_kj - default_kj) / default_kj),
            fnum(agg.energy_mean_kj - app.optimal_energy_kj(), 2),
            fnum(100.0 * (agg.time_mean_s / app.t_max_s - 1.0), 2),
            fnum(agg.switches_mean, 0),
        ]);
    }
    println!("{}", table.render());
    println!(
        "best static = {} @ {:.2} kJ; EnergyUCB should sit within ~1% of it.",
        freqs.label(app.optimal_arm()),
        app.optimal_energy_kj()
    );
}
