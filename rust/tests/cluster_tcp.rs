//! TCP-transport integration: remote-style workers dial the leader's
//! listener, receive their shard batches over the same framed-JSONL
//! grammar as the pipe transport, and the merged report is byte-identical
//! to the in-process reference — including runs where a worker is killed
//! mid-stream and the leader requeues its shard onto survivors
//! (EXPERIMENTS.md §Cluster).

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use energyucb::cluster::{
    ClusterConfig, Leader, NodeAssignment, ScenarioSchedule, Subprocess, Tcp, Transport,
};
use energyucb::control::SessionCfg;

/// The cargo-built CLI (leader and worker are the same binary). Tests
/// must pass it explicitly: `current_exe()` inside a test harness would
/// re-enter the *test* binary, not `energyucb`.
const BIN: &str = env!("CARGO_BIN_EXE_energyucb");

/// Short sessions keep the library-level cases cheap; the CLI-level
/// chaos test below runs the full `chaos` scenario.
fn test_cfg(jobs: usize) -> ClusterConfig {
    ClusterConfig {
        jobs,
        heartbeat_steps: 100,
        session: SessionCfg { max_steps: 400, ..SessionCfg::default() },
        ..ClusterConfig::default()
    }
}

/// A scaled-down mixed-scenario batch (staggered budgets cut 10x, as the
/// property suite does, to bound test wall-clock).
fn test_assignments(nodes: usize) -> Vec<NodeAssignment> {
    let schedule = ScenarioSchedule::preset("mixed", 21).unwrap();
    let mut assignments = schedule.assignments(nodes).unwrap();
    for a in &mut assignments {
        a.max_steps = a.max_steps.map(|m| (m / 10).max(1));
    }
    assignments
}

/// Spawn a worker process that dials `addr`; `die_after` arms the chaos
/// hook (`--die-after-events N`: exit abruptly after the Nth event frame).
fn spawn_worker(addr: &str, die_after: Option<u64>) -> Child {
    let mut cmd = Command::new(BIN);
    cmd.args(["cluster-worker", "--connect", addr]);
    if let Some(n) = die_after {
        cmd.args(["--die-after-events", &n.to_string()]);
    }
    cmd.stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn cluster-worker")
}

/// Block until `want` workers have connected (bounded, so a broken accept
/// path fails the test instead of hanging it).
fn wait_for_workers(t: &Tcp, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while t.capacity() != Some(want) {
        assert!(Instant::now() < deadline, "workers never connected (want {want})");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The tentpole contract: TCP shards reproduce the in-process report
/// byte-for-byte, at several shard/worker widths — including `shards >
/// workers`, where connections are pooled and reused across batches.
#[test]
fn tcp_shards_match_the_in_process_pool_byte_for_byte() {
    let assignments = test_assignments(6);
    let leader = Leader::new(test_cfg(2));
    let baseline = leader.run(&assignments).unwrap();
    for (shards, workers) in [(1usize, 1usize), (3, 3), (3, 2)] {
        let t = Tcp::listen("127.0.0.1:0", Duration::from_secs(60)).unwrap();
        let addr = t.local_addr().unwrap().to_string();
        let children: Vec<Child> = (0..workers).map(|_| spawn_worker(&addr, None)).collect();
        let report = leader.run_sharded(&assignments, shards, &t).unwrap();
        assert_eq!(
            report.render(),
            baseline.render(),
            "tcp --shards {shards} ({workers} workers)"
        );
        assert_eq!(
            report.to_csv().render(),
            baseline.to_csv().render(),
            "tcp --shards {shards} ({workers} workers) csv"
        );
        // Dropping the listener EOFs every worker socket: they exit clean.
        drop(t);
        for mut c in children {
            let _ = c.wait();
        }
    }
}

/// Kill a worker mid-stream and the leader requeues its shard onto the
/// survivors — and the recovered report is *still* byte-identical to the
/// failure-free reference. The dying worker connects first, so its
/// connection sits at the front of the idle pool and is guaranteed to be
/// handed a round-0 shard (every shard emits >= 2 frames, so
/// `--die-after-events 1` always severs it mid-batch).
#[test]
fn killed_worker_requeues_onto_survivors_byte_identically() {
    let assignments = test_assignments(6);
    let leader = Leader::new(test_cfg(2));
    let baseline = leader.run(&assignments).unwrap();

    let t = Tcp::listen("127.0.0.1:0", Duration::from_secs(60)).unwrap();
    let addr = t.local_addr().unwrap().to_string();
    let victim = spawn_worker(&addr, Some(1));
    wait_for_workers(&t, 1); // victim is first in the idle queue
    let survivors: Vec<Child> = (0..2).map(|_| spawn_worker(&addr, None)).collect();
    wait_for_workers(&t, 3);

    let report = leader.run_sharded(&assignments, 3, &t).unwrap();
    assert_eq!(report.render(), baseline.render(), "requeued run must match failure-free run");
    assert_eq!(report.to_csv().render(), baseline.to_csv().render());

    drop(t);
    for mut c in survivors.into_iter().chain([victim]) {
        let _ = c.wait();
    }
}

/// A connected-but-silent worker (hung host) trips the per-shard read
/// deadline; with nobody else to requeue onto, the run fails *in bounded
/// time* — the leader never blocks indefinitely on a dead peer.
#[test]
fn hung_worker_fails_the_run_in_bounded_time() {
    let assignments = test_assignments(2);
    let leader = Leader::new(test_cfg(1));
    let t = Tcp::listen("127.0.0.1:0", Duration::from_secs(1)).unwrap();
    let addr = t.local_addr().unwrap();
    let _fake = std::net::TcpStream::connect(addr).unwrap(); // never speaks
    let start = Instant::now();
    let e = leader.run_sharded(&assignments, 1, &t).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("no surviving tcp workers"), "{msg}");
    assert!(msg.contains("no frame within"), "{msg}");
    assert!(start.elapsed() < Duration::from_secs(30), "deadline did not bound the wait");
}

/// The pipe transport detects mid-stream worker death the same way: a
/// worker that dies between its first event and the terminal frame
/// surfaces as a clean "stream ended" error (here with requeueing
/// disabled, so the death itself is the reported failure).
#[test]
fn subprocess_mid_stream_death_is_a_clean_error() {
    let assignments = test_assignments(2);
    let leader = Leader::new(ClusterConfig { shard_retries: 0, ..test_cfg(1) });
    let t = Subprocess::with_program(BIN)
        .with_worker_args(["--die-after-events", "1"])
        .with_timeout(Duration::from_secs(60));
    let e = leader.run_sharded(&assignments, 1, &t).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("stream ended without a terminal frame"), "{msg}");
}

/// End to end through the real CLI: `--transport tcp` with a scripted
/// worker kill (`--chaos-kill 0:1`) produces stdout byte-identical to the
/// plain in-process run of the same chaos scenario.
#[test]
fn cli_chaos_kill_run_matches_the_in_process_report() {
    let run = |extra: &[&str]| -> String {
        let mut cmd = Command::new(BIN);
        cmd.args(["cluster", "--scenario", "chaos", "--nodes", "6", "--seed", "3", "--jobs", "2"]);
        cmd.args(extra);
        let out = cmd.output().expect("spawn energyucb");
        assert!(
            out.status.success(),
            "exit {:?}\nstderr: {}",
            out.status,
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8(out.stdout).unwrap()
    };
    let reference = run(&[]);
    let chaos = run(&[
        "--transport", "tcp", "--shards", "3", "--workers", "3", "--chaos-kill", "0:1",
    ]);
    assert_eq!(chaos, reference, "chaos TCP stdout differs from the in-process reference");
}
