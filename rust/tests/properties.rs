//! Property-based integration tests over coordinator invariants, using the
//! in-tree `proptest_lite` substrate (routing/selection, accounting, state
//! management — the L3 invariants the brief calls out).

use energyucb::bandit::{
    ConstrainedEnergyUcb, EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Policy,
    RoundRobin, Ucb1,
};
use energyucb::sim::freq::{DvfsState, FreqDomain, SwitchCost};
use energyucb::testutil::proptest_lite::{forall_seeded, Gen};
use energyucb::util::Rng;

/// Every policy must only ever select arms in range, for any reward stream.
#[test]
fn prop_policies_select_in_range() {
    struct Case;
    impl Gen for Case {
        type Value = (u64, usize, Vec<f64>);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            let seed = rng.next_u64();
            let k = 2 + rng.index(14);
            let rewards = (0..200).map(|_| rng.uniform_range(-3.0, 0.0)).collect();
            (seed, k, rewards)
        }
    }
    forall_seeded(1, 40, Case, |(seed, k, rewards)| {
        let k = *k;
        let mut policies: Vec<Box<dyn Policy>> = vec![
            Box::new(EnergyUcb::new(k, EnergyUcbConfig::default())),
            Box::new(ConstrainedEnergyUcb::new(k, EnergyUcbConfig::default(), 0.1)),
            Box::new(Ucb1::new(k, 0.05)),
            Box::new(EpsilonGreedy::new(k, 0.1, 10.0, *seed)),
            Box::new(EnergyTs::default_for(k, *seed)),
            Box::new(RoundRobin::new(k)),
        ];
        for policy in policies.iter_mut() {
            for (i, r) in rewards.iter().enumerate() {
                let t = (i + 1) as u64;
                let arm = policy.select(t);
                if arm >= k {
                    return false;
                }
                policy.update(arm, *r, 1e-4);
            }
        }
        true
    });
}

/// Pull counts always sum to the number of updates; reset really resets.
#[test]
fn prop_energyucb_count_conservation() {
    struct Case;
    impl Gen for Case {
        type Value = (u64, usize);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (rng.next_u64(), 10 + rng.index(500))
        }
    }
    forall_seeded(2, 50, Case, |(seed, steps)| {
        let mut rng = Rng::new(*seed);
        let mut p = EnergyUcb::new(9, EnergyUcbConfig::default());
        for t in 1..=*steps as u64 {
            let arm = p.select(t);
            p.update(arm, rng.normal(-1.0, 0.1), 1e-4);
        }
        let total: f64 = (0..9).map(|i| p.count(i)).sum();
        if (total - *steps as f64).abs() > 1e-9 {
            return false;
        }
        p.reset();
        (0..9).all(|i| p.count(i) == 0.0)
    });
}

/// The SA-UCB index is monotone in the mean and anti-monotone in the
/// switching penalty.
#[test]
fn prop_saucb_monotonicity() {
    struct Case;
    impl Gen for Case {
        type Value = (f64, f64, u64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                rng.uniform_range(-2.0, 0.0),
                rng.uniform_range(0.0, 0.3),
                2 + rng.below(100_000),
            )
        }
    }
    forall_seeded(3, 100, Case, |(mean, lambda, t)| {
        let mk = |lam: f64, reward: f64| {
            let mut p = EnergyUcb::new(
                3,
                EnergyUcbConfig { lambda: lam, ..EnergyUcbConfig::default() },
            );
            p.update(1, reward, 0.0); // prev = 1
            p
        };
        // Higher mean -> higher index for that arm.
        let lo = mk(*lambda, *mean);
        let hi = mk(*lambda, *mean + 0.5);
        if hi.sa_ucb(1, *t) <= lo.sa_ucb(1, *t) {
            return false;
        }
        // Larger lambda -> lower index for non-prev arms, unchanged for prev.
        let small = mk(0.0, *mean);
        let big = mk(*lambda, *mean);
        big.sa_ucb(0, *t) <= small.sa_ucb(0, *t) + 1e-12
            && (big.sa_ucb(1, *t) - small.sa_ucb(1, *t)).abs() < 1e-12
    });
}

/// DVFS accounting: switch count equals the number of actual transitions,
/// and overheads are exactly count × unit cost.
#[test]
fn prop_dvfs_accounting() {
    struct Case;
    impl Gen for Case {
        type Value = Vec<usize>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.index(300)).map(|_| rng.index(9)).collect()
        }
        fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
            if v.len() > 1 {
                vec![v[..v.len() / 2].to_vec()]
            } else {
                Vec::new()
            }
        }
    }
    forall_seeded(4, 60, Case, |requests| {
        let freqs = FreqDomain::aurora();
        let cost = SwitchCost::default();
        let mut dvfs = DvfsState::new(&freqs, cost);
        let mut expected = 0u64;
        let mut current = freqs.max_arm();
        for &arm in requests {
            if arm != current {
                expected += 1;
                current = arm;
            }
            dvfs.request(arm);
        }
        dvfs.switches() == expected
            && (dvfs.switch_energy_j() - expected as f64 * cost.energy_j).abs() < 1e-9
            && (dvfs.switch_time_s() - expected as f64 * cost.latency_s).abs() < 1e-12
    });
}

/// Constrained EnergyUCB never leaves an empty feasible set and never
/// selects an arm it has measured as over-budget (after estimates settle).
#[test]
fn prop_constrained_feasibility() {
    struct Case;
    impl Gen for Case {
        type Value = (u64, f64);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (rng.next_u64(), rng.uniform_range(0.01, 0.5))
        }
    }
    forall_seeded(5, 30, Case, |(seed, delta)| {
        let mut rng = Rng::new(*seed);
        let mut p = ConstrainedEnergyUcb::new(9, EnergyUcbConfig::default(), *delta);
        // True progress follows an Amdahl curve.
        let progress = |arm: usize| {
            let f = 0.8 + 0.1 * arm as f64;
            1e-3 / (0.4 + 0.6 * (1.6 / f))
        };
        for t in 1..=2000u64 {
            let arm = p.select(t);
            if arm >= 9 {
                return false;
            }
            p.update(arm, rng.normal(-1.0, 0.05), progress(arm));
        }
        // Feasible set must contain the max arm.
        p.feasible_set()[8]
    });
}
