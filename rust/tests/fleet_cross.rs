//! Integration: the AOT HLO fleet engine vs the pure-Rust native step.
//!
//! Same params, same hyper, same noise stream (the rust RNG feeds both) —
//! the two engines must produce matching trajectories. This is the proof
//! that the three layers compose: Pallas kernel → JAX step → HLO text →
//! PJRT execution from rust.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::path::Path;

use energyucb::fleet::{native, FleetEngine, FleetHyper, FleetParams, FleetState};
use energyucb::runtime::XlaRuntime;
use energyucb::sim::freq::FreqDomain;
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn crate_argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

fn artifact_dir() -> Option<&'static Path> {
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("fleet_step_b64.hlo.txt").exists().then_some(dir)
}

fn setup_b64() -> (FleetParams, Vec<&'static str>) {
    // 64 envs: 9 apps cycled.
    let names: Vec<&'static str> = calibration::APP_NAMES
        .iter()
        .cycle()
        .take(64)
        .copied()
        .collect();
    let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
    let refs: Vec<&_> = apps.iter().collect();
    let freqs = FreqDomain::aurora();
    (FleetParams::from_apps(&refs, &freqs, 0.01), names)
}

#[test]
fn hlo_engine_matches_native_trajectory() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let runtime = XlaRuntime::cpu().expect("PJRT CPU client");
    let (params, _) = setup_b64();
    let hyper = FleetHyper::default();
    let engine = FleetEngine::load(&runtime, dir, params.clone(), hyper).expect("load");

    let mut hlo_state = FleetState::fresh(64, 9);
    let mut nat_state = FleetState::fresh(64, 9);
    let mut rng = Rng::new(42);

    let steps = 400u64;
    let mut agree = 0u64;
    let mut total = 0u64;
    for step in 0..steps {
        let noise = native::step_noise(&params, step, &mut rng);
        let sel_hlo = engine.step(&mut hlo_state, &noise).expect("hlo step");
        let sel_nat = native::native_step(&mut nat_state, &params, &hyper, &noise);
        total += sel_hlo.len() as u64;
        agree += sel_hlo.iter().zip(&sel_nat).filter(|(a, b)| a == b).count() as u64;
    }
    // Identical up to f32 op-ordering; near-ties may rarely flip.
    let rate = agree as f64 / total as f64;
    assert!(rate > 0.995, "selection agreement {rate}");

    // Aggregate accounting must agree tightly.
    for e in 0..64 {
        let eh = hlo_state.cum_energy[e] as f64;
        let en = nat_state.cum_energy[e] as f64;
        assert!(
            (eh - en).abs() / en.max(1.0) < 0.01,
            "env {e}: hlo {eh} vs native {en}"
        );
    }
}

#[test]
fn hlo_engine_converges_on_calibrated_apps() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let runtime = XlaRuntime::cpu().expect("PJRT CPU client");
    let (params, names) = setup_b64();
    let engine =
        FleetEngine::load(&runtime, dir, params.clone(), FleetHyper::default()).expect("load");
    let mut state = FleetState::fresh(64, 9);
    let mut rng = Rng::new(7);
    for step in 0..3000u64 {
        let noise = native::step_noise(&params, step, &mut rng);
        engine.step(&mut state, &noise).expect("step");
    }
    // The modal arm must be energy-near-optimal. (Several apps have
    // sub-1 % gaps between adjacent arms — e.g. clvleaf's 88.41 vs 89.00 —
    // so requiring the exact argmin would over-fit the noise.)
    for (e, name) in names.iter().enumerate().take(9) {
        let app = calibration::app(name).unwrap();
        let row = &state.n[e * 9..(e + 1) * 9];
        let modal = crate_argmax(row);
        let gap = app.energy_kj[modal] / app.optimal_energy_kj() - 1.0;
        // 3000 steps is mid-convergence for the long, small-gap apps
        // (sph_exa's 0.8 vs 1.0 GHz differ by 2.4%); full-horizon
        // convergence is covered by the table1 experiment.
        assert!(
            gap < 0.03,
            "{name}: modal arm {modal} is {:.2}% above optimal (pulls {row:?})",
            gap * 100.0
        );
    }
}

#[test]
fn saucb_artifact_loads_and_runs() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let path = dir.join("saucb_b64.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP: saucb artifact missing");
        return;
    }
    let runtime = XlaRuntime::cpu().expect("PJRT CPU client");
    let module = runtime.load_hlo_text(&path).expect("load saucb");
    use energyucb::runtime::literal;
    let b = 64;
    let k = 9;
    let mu: Vec<f32> = (0..b * k).map(|i| -1.0 - 0.01 * (i % k) as f32).collect();
    let n = vec![5.0f32; b * k];
    let prev = vec![8i32; b];
    let feas = vec![1.0f32; b * k];
    let inputs = vec![
        literal::mat_f32(&mu, b, k).unwrap(),
        literal::mat_f32(&n, b, k).unwrap(),
        literal::vec_i32(&prev),
        literal::mat_f32(&feas, b, k).unwrap(),
        literal::scalar_f32(0.0),  // alpha
        literal::scalar_f32(0.0),  // lam
        literal::scalar_f32(100.0) // t
    ];
    let out = module.run(&inputs).expect("run saucb");
    assert_eq!(out.len(), 2);
    let sel = literal::to_vec_i32(&out[1]).unwrap();
    // With alpha=lam=0 the best mu (arm 0, the least negative) wins.
    assert!(sel.iter().all(|&s| s == 0), "{sel:?}");
}
