//! End-to-end record→replay: a session recorded through the `Recording`
//! tee and replayed through `ReplayBackend` under the same policy config
//! must reproduce the original run's metrics *exactly* (bit-for-bit
//! floats) — the backend determinism guarantee of EXPERIMENTS.md
//! §Controller — for every shipped policy. Also covers counterfactual
//! replay (a different policy over the frozen sample stream) and the
//! file-based CLI-shaped path.

use energyucb::bandit::CONTEXT_DIM;
use energyucb::config::ExperimentConfig;
use energyucb::control::{
    drive, sweep_replay, BackendTotals, Controller, Recording, ReplayBackend, ReplayHeader,
    RunResult, SessionCfg, SimBackend, StepSample, SweepCandidate, TelemetryFrame,
};
use energyucb::fleet::{fleet_controller, FleetBackend, FleetParams, FleetState};
use energyucb::sim::freq::FreqDomain;
use energyucb::testutil::{forall_seeded, Gen};
use energyucb::util::Rng;
use energyucb::workload::calibration;
use energyucb::workload::model::AppModel;
use energyucb::workload::serving::{ServingCfg, ServingModel};

/// Every policy name the config surface ships.
const POLICIES: [&str; 10] = [
    "energyucb",
    "constrained",
    "ucb1",
    "swucb",
    "egreedy",
    "energyts",
    "rrfreq",
    "static",
    "rlpower",
    "drlcap",
];

fn policy_config(name: &str) -> energyucb::config::PolicyConfig {
    ExperimentConfig::from_toml(&format!("[policy]\nname = \"{name}\"\n"))
        .unwrap()
        .policy
}

/// Record one session into an in-memory JSONL buffer; return the run and
/// the log text.
fn record(
    app: &AppModel,
    pcfg: &energyucb::config::PolicyConfig,
    cfg: &SessionCfg,
) -> (RunResult, String) {
    let mut policy = pcfg.build(cfg.freqs.k(), cfg.seed);
    policy.reset();
    let header =
        ReplayHeader::session(app.name.to_string(), Some(pcfg.clone()), cfg.clone());
    let mut buf: Vec<u8> = Vec::new();
    let mut backend = Recording::new(SimBackend::new(app, cfg), &mut buf, &header).unwrap();
    let controller = Controller::new(app, policy.as_mut(), cfg);
    let result = drive(controller, &mut backend).unwrap().pop().unwrap();
    backend.finish().unwrap();
    (result, String::from_utf8(buf).unwrap())
}

/// Replay a recorded log under the policy config in its header.
fn replay(app: &AppModel, log: &str) -> RunResult {
    let mut backend = ReplayBackend::from_text(log).unwrap();
    let header = backend.header().clone();
    let scfg = header.session.clone();
    let mut policy = header.policy.expect("recorded policy").build(scfg.freqs.k(), scfg.seed);
    policy.reset();
    let controller = Controller::new(app, policy.as_mut(), &scfg);
    drive(controller, &mut backend).unwrap().pop().unwrap()
}

#[test]
fn record_then_replay_is_exact_for_every_shipped_policy() {
    let app = calibration::app("tealeaf").unwrap();
    // Capped runs keep the full 10-policy sweep fast; the uncapped case
    // is covered separately below.
    let cfg = SessionCfg { seed: 11, max_steps: 1_200, ..SessionCfg::default() };
    for name in POLICIES {
        let pcfg = policy_config(name);
        let (original, log) = record(&app, &pcfg, &cfg);
        let replayed = replay(&app, &log);
        // Exact equality: RunMetrics is PartialEq over raw f64s.
        assert_eq!(replayed.metrics, original.metrics, "{name}");
        assert_eq!(
            replayed.energy_checkpoints_j, original.energy_checkpoints_j,
            "{name}: checkpoints"
        );
        match (&original.trace, &replayed.trace) {
            (None, None) => {}
            (a, b) => assert_eq!(
                a.as_ref().map(|t| t.len()),
                b.as_ref().map(|t| t.len()),
                "{name}: trace"
            ),
        }
    }
}

#[test]
fn record_then_replay_is_exact_on_a_full_run() {
    let app = calibration::app("clvleaf").unwrap();
    let cfg = SessionCfg { seed: 3, record_trace: true, ..SessionCfg::default() };
    let pcfg = policy_config("energyucb");
    let (original, log) = record(&app, &pcfg, &cfg);
    assert!((original.metrics.completed - 1.0).abs() < 1e-9, "ran to completion");
    let replayed = replay(&app, &log);
    assert_eq!(replayed.metrics, original.metrics);
    // The replayed trace reproduces every step bit-for-bit (decisions,
    // rewards, regret — all recomputed from the recorded samples).
    assert_eq!(
        replayed.trace.unwrap().steps(),
        original.trace.unwrap().steps()
    );
}

#[test]
fn counterfactual_replay_runs_a_different_policy_over_frozen_samples() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 7, max_steps: 600, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("static"), &cfg);

    let mut backend = ReplayBackend::from_text(&log).unwrap();
    let scfg = backend.header().session.clone();
    let mut policy = policy_config("rrfreq").build(scfg.freqs.k(), scfg.seed);
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let counterfactual = drive(controller, &mut backend).unwrap().pop().unwrap();

    // Decisions (and thus regret accounting) are the new policy's...
    assert_eq!(counterfactual.metrics.policy, "RRFreq");
    assert_ne!(counterfactual.metrics.cumulative_regret, original.metrics.cumulative_regret);
    // ...while the energy totals stay the recorded run's (open loop).
    assert_eq!(counterfactual.metrics.gpu_energy_kj, original.metrics.gpu_energy_kj);
    assert_eq!(counterfactual.metrics.steps, original.metrics.steps);
}

#[test]
fn file_round_trip_matches_in_memory() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 5, max_steps: 400, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("ucb1"), &cfg);
    let dir = std::env::temp_dir().join(format!("energyucb_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.jsonl");
    std::fs::write(&path, &log).unwrap();
    let mut backend = ReplayBackend::open(&path).unwrap();
    assert_eq!(backend.len(), original.metrics.steps as usize);
    let scfg = backend.header().session.clone();
    let mut policy =
        backend.header().policy.clone().unwrap().build(scfg.freqs.k(), scfg.seed);
    policy.reset();
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let replayed = drive(controller, &mut backend).unwrap().pop().unwrap();
    assert_eq!(replayed.metrics, original.metrics);
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// Contextual (serving-tier) grammar: the versioned extension must round-
// trip exactly, reject malformed context blocks, reproduce contextual
// runs bit-for-bit through record→replay at B ∈ {1, 32}, and leave the
// legacy context-free byte shapes untouched.
// ---------------------------------------------------------------------

/// Record one *serving* session (contextual samples + QoS budget in the
/// header) into an in-memory JSONL buffer.
fn record_serving(
    app: &AppModel,
    pcfg: &energyucb::config::PolicyConfig,
    cfg: &SessionCfg,
    srv: &ServingCfg,
) -> (RunResult, String) {
    let mut policy = pcfg.build(cfg.freqs.k(), cfg.seed);
    policy.reset();
    let header = ReplayHeader::session(app.name.to_string(), Some(pcfg.clone()), cfg.clone())
        .with_context(Some(srv.ttft_budget));
    let mut buf: Vec<u8> = Vec::new();
    let mut backend = Recording::new(
        SimBackend::new(app, cfg).with_serving(ServingModel::new(srv.clone())),
        &mut buf,
        &header,
    )
    .unwrap();
    let controller =
        Controller::new(app, policy.as_mut(), cfg).with_qos_budget(Some(srv.ttft_budget));
    let result = drive(controller, &mut backend).unwrap().pop().unwrap();
    backend.finish().unwrap();
    (result, String::from_utf8(buf).unwrap())
}

#[test]
fn serving_record_then_replay_is_exact_at_b1() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 17, max_steps: 800, ..SessionCfg::default() };
    let srv = ServingCfg::default();
    for name in ["linucb", "clinucb", "static"] {
        let pcfg = policy_config(name);
        let (original, log) = record_serving(&app, &pcfg, &cfg, &srv);
        assert!(
            original.metrics.qos_violation_frac.is_some(),
            "{name}: serving run reported no QoS fraction"
        );
        let mut backend = ReplayBackend::from_text(&log).unwrap();
        let header = backend.header().clone();
        assert_eq!(header.context.unwrap().dim, CONTEXT_DIM, "{name}");
        let mut policy =
            header.policy.clone().unwrap().build(header.session.freqs.k(), header.session.seed);
        policy.reset();
        let controller = Controller::new(&app, policy.as_mut(), &header.session)
            .with_qos_budget(header.context.and_then(|c| c.qos_budget));
        let replayed = drive(controller, &mut backend).unwrap().pop().unwrap();
        assert_eq!(replayed.metrics, original.metrics, "{name}");
    }
}

#[test]
fn serving_fleet_record_then_sweep_replay_is_exact_at_b32() {
    let b = 32usize;
    let freqs = FreqDomain::aurora();
    let names = ["tealeaf", "clvleaf"];
    let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
    let assigned: Vec<&_> = apps.iter().cycle().take(b).collect();
    let params = FleetParams::from_apps(&assigned, &freqs, 0.01);
    let pcfg = policy_config("linucb");
    let steps = 200u64;
    let seed = 5u64;
    let srv = ServingCfg::default();
    let scfg = SessionCfg {
        seed,
        dt_s: params.dt_s,
        max_steps: steps,
        freqs: freqs.clone(),
        ..SessionCfg::default()
    };
    let env_names: Vec<String> = names.iter().cycle().take(b).map(|s| s.to_string()).collect();
    let header = ReplayHeader::fleet(env_names, Some(pcfg.clone()), scfg.clone(), None)
        .with_context(Some(srv.ttft_budget));
    let mut state = FleetState::fresh(b, freqs.k());
    let mut rng = Rng::new(seed);
    let mut buf: Vec<u8> = Vec::new();
    let original = {
        let mut policy = pcfg.build_batch(b, freqs.k(), seed);
        let models: Vec<ServingModel> = (0..b)
            .map(|e| ServingModel::new(ServingCfg { seed: srv.seed + e as u64, ..srv.clone() }))
            .collect();
        let controller = fleet_controller(&params, Box::new(policy.as_mut()), steps)
            .with_qos_budget(Some(srv.ttft_budget));
        let inner = FleetBackend::new(&mut state, &params, &mut rng).with_serving(models);
        let mut backend = Recording::new(inner, &mut buf, &header).unwrap();
        let results = drive(controller, &mut backend).unwrap();
        backend.finish().unwrap();
        results
    };
    let log = String::from_utf8(buf).unwrap();
    // Sweeping the recording's own policy over the frozen contextual
    // trace reproduces every row's metrics bit-for-bit.
    let trace = ReplayBackend::from_text(&log).unwrap();
    let swept = sweep_replay(&trace, &[SweepCandidate::new(pcfg)], 2).unwrap();
    assert_eq!(swept[0].results.len(), b);
    for (e, (orig, rep)) in original.iter().zip(&swept[0].results).enumerate() {
        assert_eq!(rep.metrics, orig.metrics, "env {e}");
    }
}

/// Step samples with (and without) context blocks, exercising the full
/// optional-field surface of the extended grammar.
struct CtxSampleGen;

impl Gen for CtxSampleGen {
    type Value = StepSample;

    fn generate(&self, rng: &mut Rng) -> StepSample {
        let mut ctx = [0.0f64; CONTEXT_DIM];
        for c in &mut ctx {
            *c = rng.uniform_range(-10.0, 50.0);
        }
        StepSample {
            gpu_energy_j: rng.uniform_range(0.0, 100.0),
            core_util: rng.uniform(),
            uncore_util: rng.uniform(),
            progress: rng.uniform_range(0.0, 1e-2),
            remaining: rng.uniform(),
            true_gpu_energy_j: rng.uniform_range(0.0, 100.0),
            switched: rng.chance(0.5),
            reward: if rng.chance(0.5) { Some(-rng.uniform()) } else { None },
            context: if rng.chance(0.8) { Some(ctx) } else { None },
            ..StepSample::default()
        }
    }
}

#[test]
fn context_frames_round_trip_exactly() {
    forall_seeded(0xC0_47E7, 300, CtxSampleGen, |s| {
        let scalar = TelemetryFrame::Step { arms: vec![4], samples: vec![s.clone()] };
        let batch = TelemetryFrame::Step {
            arms: vec![4, 7],
            samples: vec![s.clone(), StepSample { context: None, ..s.clone() }],
        };
        [scalar, batch].into_iter().all(|f| {
            let line = f.encode_line();
            !line.contains('\n') && TelemetryFrame::decode_line(&line).ok() == Some(f)
        })
    });
}

#[test]
fn malformed_context_blocks_are_rejected() {
    // Context vectors of any width other than CONTEXT_DIM never decode.
    for n in [0usize, 1, CONTEXT_DIM - 1, CONTEXT_DIM + 1, 16] {
        let vals = vec!["0.5"; n].join(",");
        let line = format!(
            "{{\"kind\":\"step\",\"arm\":1,\"sample\":{{\"gpu_energy_j\":1.5,\"core_util\":0.5,\
             \"uncore_util\":0.25,\"progress\":0.125,\"remaining\":0.75,\
             \"true_gpu_energy_j\":1.375,\"switched\":false,\"context\":[{vals}]}}}}"
        );
        assert!(TelemetryFrame::decode_line(&line).is_err(), "dim {n} decoded");
    }
    // Non-numeric context payloads are rejected, not coerced.
    let bad = "{\"kind\":\"step\",\"arm\":1,\"sample\":{\"gpu_energy_j\":1.5,\"core_util\":0.5,\
               \"uncore_util\":0.25,\"progress\":0.125,\"remaining\":0.75,\
               \"true_gpu_energy_j\":1.375,\"switched\":false,\"context\":\"four\"}}";
    assert!(TelemetryFrame::decode_line(bad).is_err());

    let end = TelemetryFrame::End {
        totals: vec![BackendTotals::default()],
        steps: Some(1),
        truncated: false,
    }
    .encode_line();
    let ctx_step = TelemetryFrame::Step {
        arms: vec![0],
        samples: vec![StepSample {
            context: Some([1.0, 2.0, 3.0, 4.0]),
            ..StepSample::default()
        }],
    }
    .encode_line();

    // A contextual step inside a log whose header declares no context
    // spec is malformed, not silently accepted.
    let plain = ReplayHeader::session("tealeaf".into(), None, SessionCfg::default());
    let text =
        format!("{}\n{ctx_step}\n{end}\n", TelemetryFrame::Header(plain).encode_line());
    let err = ReplayBackend::from_text(&text).unwrap_err().to_string();
    assert!(err.contains("declares no context spec"), "{err}");

    // A header declaring an alien context width is refused up front.
    let mut alien = ReplayHeader::session("tealeaf".into(), None, SessionCfg::default())
        .with_context(None);
    alien.context.as_mut().unwrap().dim = 7;
    let text = format!("{}\n{ctx_step}\n{end}\n", TelemetryFrame::Header(alien).encode_line());
    let err = ReplayBackend::from_text(&text).unwrap_err().to_string();
    assert!(err.contains("dim = 7"), "{err}");
}

#[test]
fn pinned_legacy_lines_decode_and_reencode_byte_identically() {
    // Pre-context grammar bytes, written out literally: the contextual
    // extension must leave them untouched in both directions.
    let step = "{\"kind\":\"step\",\"arm\":8,\"sample\":{\"gpu_energy_j\":1.5,\
                \"core_util\":0.5,\"uncore_util\":0.25,\"progress\":0.125,\
                \"remaining\":0.75,\"true_gpu_energy_j\":1.375,\"switched\":false}}"
        .replace(char::is_whitespace, "");
    let end = "{\"kind\":\"end\",\"totals\":{\"gpu_energy_kj\":1.25,\"exec_time_s\":2.5,\
               \"switches\":3,\"switch_energy_j\":0.375,\"switch_time_s\":0.125},\"steps\":1}"
        .replace(char::is_whitespace, "");
    for line in [&step, &end] {
        let f = TelemetryFrame::decode_line(line).unwrap();
        assert_eq!(&f.encode_line(), line);
    }
    // And a freshly recorded context-free session never grows context or
    // QoS keys anywhere in the log.
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 11, max_steps: 300, ..SessionCfg::default() };
    let (_, log) = record(&app, &policy_config("static"), &cfg);
    assert!(!log.contains("\"context\""), "context key leaked into a context-free log");
    assert!(!log.contains("qos"), "qos key leaked into a context-free log");
}

#[test]
fn replaying_under_a_different_seed_policy_diverges() {
    // Sanity guard on the guarantee's precondition: the *same* policy
    // config but a different seed is a different controller — seeded
    // policies must not accidentally ignore their seed.
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 21, max_steps: 900, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("egreedy"), &cfg);
    let mut backend = ReplayBackend::from_text(&log).unwrap();
    let scfg = backend.header().session.clone();
    let mut policy = policy_config("egreedy").build(scfg.freqs.k(), scfg.seed + 1);
    policy.reset();
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let other = drive(controller, &mut backend).unwrap().pop().unwrap();
    assert_ne!(
        other.metrics.cumulative_regret,
        original.metrics.cumulative_regret
    );
}
