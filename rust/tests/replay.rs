//! End-to-end record→replay: a session recorded through the `Recording`
//! tee and replayed through `ReplayBackend` under the same policy config
//! must reproduce the original run's metrics *exactly* (bit-for-bit
//! floats) — the backend determinism guarantee of EXPERIMENTS.md
//! §Controller — for every shipped policy. Also covers counterfactual
//! replay (a different policy over the frozen sample stream) and the
//! file-based CLI-shaped path.

use energyucb::config::ExperimentConfig;
use energyucb::control::{
    drive, Controller, Recording, ReplayBackend, ReplayHeader, RunResult, SessionCfg, SimBackend,
};
use energyucb::workload::calibration;
use energyucb::workload::model::AppModel;

/// Every policy name the config surface ships.
const POLICIES: [&str; 10] = [
    "energyucb",
    "constrained",
    "ucb1",
    "swucb",
    "egreedy",
    "energyts",
    "rrfreq",
    "static",
    "rlpower",
    "drlcap",
];

fn policy_config(name: &str) -> energyucb::config::PolicyConfig {
    ExperimentConfig::from_toml(&format!("[policy]\nname = \"{name}\"\n"))
        .unwrap()
        .policy
}

/// Record one session into an in-memory JSONL buffer; return the run and
/// the log text.
fn record(
    app: &AppModel,
    pcfg: &energyucb::config::PolicyConfig,
    cfg: &SessionCfg,
) -> (RunResult, String) {
    let mut policy = pcfg.build(cfg.freqs.k(), cfg.seed);
    policy.reset();
    let header =
        ReplayHeader::session(app.name.to_string(), Some(pcfg.clone()), cfg.clone());
    let mut buf: Vec<u8> = Vec::new();
    let mut backend = Recording::new(SimBackend::new(app, cfg), &mut buf, &header).unwrap();
    let controller = Controller::new(app, policy.as_mut(), cfg);
    let result = drive(controller, &mut backend).unwrap().pop().unwrap();
    backend.finish().unwrap();
    (result, String::from_utf8(buf).unwrap())
}

/// Replay a recorded log under the policy config in its header.
fn replay(app: &AppModel, log: &str) -> RunResult {
    let mut backend = ReplayBackend::from_text(log).unwrap();
    let header = backend.header().clone();
    let scfg = header.session.clone();
    let mut policy = header.policy.expect("recorded policy").build(scfg.freqs.k(), scfg.seed);
    policy.reset();
    let controller = Controller::new(app, policy.as_mut(), &scfg);
    drive(controller, &mut backend).unwrap().pop().unwrap()
}

#[test]
fn record_then_replay_is_exact_for_every_shipped_policy() {
    let app = calibration::app("tealeaf").unwrap();
    // Capped runs keep the full 10-policy sweep fast; the uncapped case
    // is covered separately below.
    let cfg = SessionCfg { seed: 11, max_steps: 1_200, ..SessionCfg::default() };
    for name in POLICIES {
        let pcfg = policy_config(name);
        let (original, log) = record(&app, &pcfg, &cfg);
        let replayed = replay(&app, &log);
        // Exact equality: RunMetrics is PartialEq over raw f64s.
        assert_eq!(replayed.metrics, original.metrics, "{name}");
        assert_eq!(
            replayed.energy_checkpoints_j, original.energy_checkpoints_j,
            "{name}: checkpoints"
        );
        match (&original.trace, &replayed.trace) {
            (None, None) => {}
            (a, b) => assert_eq!(
                a.as_ref().map(|t| t.len()),
                b.as_ref().map(|t| t.len()),
                "{name}: trace"
            ),
        }
    }
}

#[test]
fn record_then_replay_is_exact_on_a_full_run() {
    let app = calibration::app("clvleaf").unwrap();
    let cfg = SessionCfg { seed: 3, record_trace: true, ..SessionCfg::default() };
    let pcfg = policy_config("energyucb");
    let (original, log) = record(&app, &pcfg, &cfg);
    assert!((original.metrics.completed - 1.0).abs() < 1e-9, "ran to completion");
    let replayed = replay(&app, &log);
    assert_eq!(replayed.metrics, original.metrics);
    // The replayed trace reproduces every step bit-for-bit (decisions,
    // rewards, regret — all recomputed from the recorded samples).
    assert_eq!(
        replayed.trace.unwrap().steps(),
        original.trace.unwrap().steps()
    );
}

#[test]
fn counterfactual_replay_runs_a_different_policy_over_frozen_samples() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 7, max_steps: 600, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("static"), &cfg);

    let mut backend = ReplayBackend::from_text(&log).unwrap();
    let scfg = backend.header().session.clone();
    let mut policy = policy_config("rrfreq").build(scfg.freqs.k(), scfg.seed);
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let counterfactual = drive(controller, &mut backend).unwrap().pop().unwrap();

    // Decisions (and thus regret accounting) are the new policy's...
    assert_eq!(counterfactual.metrics.policy, "RRFreq");
    assert_ne!(counterfactual.metrics.cumulative_regret, original.metrics.cumulative_regret);
    // ...while the energy totals stay the recorded run's (open loop).
    assert_eq!(counterfactual.metrics.gpu_energy_kj, original.metrics.gpu_energy_kj);
    assert_eq!(counterfactual.metrics.steps, original.metrics.steps);
}

#[test]
fn file_round_trip_matches_in_memory() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 5, max_steps: 400, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("ucb1"), &cfg);
    let dir = std::env::temp_dir().join(format!("energyucb_replay_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.jsonl");
    std::fs::write(&path, &log).unwrap();
    let mut backend = ReplayBackend::open(&path).unwrap();
    assert_eq!(backend.len(), original.metrics.steps as usize);
    let scfg = backend.header().session.clone();
    let mut policy =
        backend.header().policy.clone().unwrap().build(scfg.freqs.k(), scfg.seed);
    policy.reset();
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let replayed = drive(controller, &mut backend).unwrap().pop().unwrap();
    assert_eq!(replayed.metrics, original.metrics);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn replaying_under_a_different_seed_policy_diverges() {
    // Sanity guard on the guarantee's precondition: the *same* policy
    // config but a different seed is a different controller — seeded
    // policies must not accidentally ignore their seed.
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 21, max_steps: 900, ..SessionCfg::default() };
    let (original, log) = record(&app, &policy_config("egreedy"), &cfg);
    let mut backend = ReplayBackend::from_text(&log).unwrap();
    let scfg = backend.header().session.clone();
    let mut policy = policy_config("egreedy").build(scfg.freqs.k(), scfg.seed + 1);
    policy.reset();
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let other = drive(controller, &mut backend).unwrap().pop().unwrap();
    assert_ne!(
        other.metrics.cumulative_regret,
        original.metrics.cumulative_regret
    );
}
