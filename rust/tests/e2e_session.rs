//! End-to-end integration: full control sessions over the calibrated suite
//! — the composition of workload models, node/GPU simulation, GEOPM
//! plumbing, reward formation, and policies.

use energyucb::bandit::{
    ConstrainedEnergyUcb, EnergyUcb, EnergyUcbConfig, Oracle, Policy, StaticPolicy,
};
use energyucb::control::{run_session, SessionCfg};
use energyucb::sim::freq::FreqDomain;
use energyucb::workload::calibration;

/// Every static frequency on every app reproduces its Table-1 cell within
/// noise (< 1 %). This is the calibration contract.
#[test]
fn statics_reproduce_table1_everywhere() {
    let freqs = FreqDomain::aurora();
    for app in calibration::all_apps() {
        // Long apps are expensive in debug; subsample arms there.
        let arms: Vec<usize> = if app.t_max_s > 100.0 {
            vec![0, 4, freqs.max_arm()]
        } else {
            freqs.arms().collect()
        };
        for arm in arms {
            let mut policy = StaticPolicy::new(freqs.k(), arm);
            let res = run_session(&app, &mut policy, &SessionCfg::default());
            let expected = app.energy_kj[arm];
            let got = res.metrics.gpu_energy_kj;
            assert!(
                (got - expected).abs() / expected < 0.01,
                "{} arm {arm}: {got} vs {expected}",
                app.name
            );
        }
    }
}

/// Oracle beats (or ties) EnergyUCB on true energy everywhere; EnergyUCB
/// beats the default; the gap to oracle is small (< 3 %).
#[test]
fn energyucb_sandwich_bounds() {
    let freqs = FreqDomain::aurora();
    for name in ["lbm", "tealeaf", "clvleaf", "miniswp", "pot3d", "weather"] {
        let app = calibration::app(name).unwrap();
        let mut ucb = EnergyUcb::new(freqs.k(), EnergyUcbConfig::default());
        let cfg = SessionCfg { seed: 11, ..SessionCfg::default() };
        let ucb_kj = run_session(&app, &mut ucb, &cfg).metrics.gpu_energy_kj;
        let mut oracle = Oracle::for_app(&app);
        let oracle_kj = run_session(&app, &mut oracle, &cfg).metrics.gpu_energy_kj;
        let default_kj = app.energy_kj[freqs.max_arm()];
        assert!(
            oracle_kj <= ucb_kj + 0.5,
            "{name}: oracle {oracle_kj} vs ucb {ucb_kj}"
        );
        // lbm's optimum IS ~the default; others must save energy.
        if name != "lbm" {
            assert!(ucb_kj < default_kj, "{name}: {ucb_kj} vs default {default_kj}");
        }
        assert!(
            ucb_kj / oracle_kj < 1.03,
            "{name}: regret too large ({ucb_kj} vs {oracle_kj})"
        );
    }
}

/// The constrained variant respects its budget on every mixed/memory app
/// while the unconstrained one may exceed it. llama is included as the
/// regression case for the switch-stall progress-estimate bias (its
/// 1.5 GHz arm sits 0.7 % under the δ = 5 % boundary and must stay
/// feasible).
#[test]
fn constrained_budget_respected_e2e() {
    let freqs = FreqDomain::aurora();
    for name in ["clvleaf", "miniswp", "weather", "llama"] {
        let app = calibration::app(name).unwrap();
        let delta = 0.05;
        let mut con = ConstrainedEnergyUcb::new(freqs.k(), EnergyUcbConfig::default(), delta);
        let cfg = SessionCfg { seed: 5, ..SessionCfg::default() };
        let res = run_session(&app, &mut con, &cfg);
        let slowdown = res.metrics.slowdown(&app);
        assert!(
            slowdown <= delta + 0.02,
            "{name}: constrained slowdown {slowdown}"
        );
        // Still saves energy vs the default (llama: must exploit the
        // boundary 1.5 GHz arm, ~20 kJ under the default).
        let default_kj = app.energy_kj[freqs.max_arm()];
        let bound = if name == "llama" { default_kj - 10.0 } else { default_kj + 0.5 };
        assert!(
            res.metrics.gpu_energy_kj < bound,
            "{name}: {} (bound {bound})",
            res.metrics.gpu_energy_kj
        );
    }
}

/// Session determinism: same seed → identical results, different seed →
/// different trajectory (for a stochastic policy).
#[test]
fn session_determinism_and_seed_sensitivity() {
    let app = calibration::app("clvleaf").unwrap();
    let run = |seed: u64| {
        let mut p = EnergyUcb::new(9, EnergyUcbConfig::default());
        let cfg = SessionCfg { seed, ..SessionCfg::default() };
        let r = run_session(&app, &mut p, &cfg);
        (r.metrics.gpu_energy_kj, r.metrics.steps, r.metrics.switches)
    };
    assert_eq!(run(1), run(1));
    assert_ne!(run(1), run(2));
}

/// Trace records exactly the run that happened: energy sums match totals,
/// switch counts match, step count matches.
#[test]
fn trace_is_consistent_with_metrics() {
    let app = calibration::app("tealeaf").unwrap();
    let mut p = EnergyUcb::new(9, EnergyUcbConfig::default());
    let cfg = SessionCfg { seed: 9, record_trace: true, ..SessionCfg::default() };
    let res = run_session(&app, &mut p, &cfg);
    let trace = res.trace.expect("trace");
    assert_eq!(trace.len() as u64, res.metrics.steps);
    assert_eq!(trace.switch_count(), res.metrics.switches);
    let trace_energy_kj: f64 =
        trace.steps().iter().map(|s| s.energy_j).sum::<f64>() / 1_000.0;
    assert!(
        (trace_energy_kj - res.metrics.gpu_energy_kj).abs() < 0.01,
        "{trace_energy_kj} vs {}",
        res.metrics.gpu_energy_kj
    );
    // Arm histogram covers all steps.
    assert_eq!(
        trace.arm_histogram(9).iter().sum::<u64>(),
        res.metrics.steps
    );
}

/// Reward-form variants run end-to-end and produce sane energies.
#[test]
fn reward_forms_end_to_end() {
    use energyucb::bandit::RewardForm;
    let app = calibration::app("clvleaf").unwrap();
    for form in [
        RewardForm::EnergyRatio,
        RewardForm::EnergySquaredRatio,
        RewardForm::EnergyRatioSquared,
    ] {
        let mut p = EnergyUcb::new(9, EnergyUcbConfig::default());
        let cfg = SessionCfg { seed: 3, reward_form: form, ..SessionCfg::default() };
        let res = run_session(&app, &mut p, &cfg);
        let kj = res.metrics.gpu_energy_kj;
        assert!(kj > 85.0 && kj < 110.0, "{}: {kj}", form.name());
    }
}
