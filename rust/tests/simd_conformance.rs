//! SIMD conformance suite: every dispatchable decision kernel must be
//! **bit-identical** to the preserved scalar reference
//! (`bandit::batch::scalar`), across the full shape matrix — B ∈
//! {1..1000} (including non-multiples of the 8/4 lane widths), K ∈
//! {1..64}, random feasibility masks (including fully-infeasible rows
//! and guaranteed exact score ties from discrete value grids),
//! `prev = -1`, zero pull counts (UCB1 warm-start), and active-mask
//! freezes (frozen rows must not move by even one bit).
//!
//! CI runs this suite twice: once under the default dispatch and once
//! with `ENERGYUCB_FORCE_SCALAR=1`, so the escape hatch itself stays
//! covered. Grid values are drawn from small discrete sets on purpose —
//! continuous draws essentially never tie, and ties are where a wrong
//! lane-merge order would show up (first-index tie-breaking is part of
//! the HLO artifact contract).

use energyucb::bandit::batch::{
    active_kernel, grid_update_batch_with, saucb_select_into_with, swucb_select_into_with,
    ucb1_select_into_with, Kernel, SaUcbHyper,
};
use energyucb::testutil::proptest_lite::{forall_seeded, Gen};
use energyucb::util::Rng;

/// Random (B, K, grid-seed) shape; shrinks toward B = 1 / K = 1 and
/// halves, keeping the grid seed so the counterexample replays.
struct Shape;

impl Gen for Shape {
    type Value = (usize, usize, u64);
    fn generate(&self, rng: &mut Rng) -> (usize, usize, u64) {
        (1 + rng.index(1000), 1 + rng.index(64), rng.next_u64())
    }
    fn shrink(&self, &(b, k, seed): &(usize, usize, u64)) -> Vec<(usize, usize, u64)> {
        let mut out = Vec::new();
        if b > 1 {
            out.push((1, k, seed));
            out.push((b / 2, k, seed));
        }
        if k > 1 {
            out.push((b, 1, seed));
            out.push((b, k / 2, seed));
        }
        out
    }
}

/// Synthesized f32 SA-UCB grids with tie-stressing discrete values.
/// Means are strictly negative (never ±0.0) so the frozen-row bitwise
/// invariance is well-defined: `x + ±0.0` is only a bitwise no-op for
/// nonzero `x`.
struct SaGrids {
    hyper: SaUcbHyper,
    n: Vec<f32>,
    mean: Vec<f32>,
    prev: Vec<i32>,
    feasible: Vec<f32>,
    reward: Vec<f64>,
    active: Vec<f32>,
    t: f32,
}

fn sa_grids(b: usize, k: usize, seed: u64) -> SaGrids {
    let mut rng = Rng::new(seed);
    // Hyper-parameter corners: prior_n = 0 with n = 0 exercises the
    // denom <= 0 → mu_init branch; lambda = 0 removes the penalty term.
    let hyper = SaUcbHyper {
        alpha: [0.0f32, 0.1, 1.0, 2.0][rng.index(4)],
        lambda: [0.0f32, 0.05, 0.5][rng.index(3)],
        mu_init: [0.0f32, -1.0][rng.index(2)],
        prior_n: [0.0f32, 1.0, 4.0][rng.index(3)],
    };
    let mut g = SaGrids {
        hyper,
        n: Vec::with_capacity(b * k),
        mean: Vec::with_capacity(b * k),
        prev: Vec::with_capacity(b),
        feasible: Vec::with_capacity(b * k),
        reward: Vec::with_capacity(b),
        active: Vec::with_capacity(b),
        t: (1 + rng.index(1000)) as f32,
    };
    for e in 0..b {
        // ~1-in-6 rows are fully infeasible (the pinned arm-0 fallback);
        // every other row keeps at least one feasible arm.
        let all_zero = rng.index(6) == 0;
        for _ in 0..k {
            g.n.push(rng.index(5) as f32);
            g.mean.push(-0.5 * (rng.index(4) + 1) as f32);
            g.feasible.push(if !all_zero && rng.chance(0.8) { 1.0 } else { 0.0 });
        }
        if !all_zero {
            g.feasible[e * k + rng.index(k)] = 1.0;
        }
        g.prev.push(rng.index(k + 1) as i32 - 1); // -1 ..= k-1
        g.reward.push(-0.5 * (rng.index(4) + 1) as f64);
        g.active.push(if rng.chance(0.25) { 0.0 } else { 1.0 });
    }
    g
}

/// Synthesized f64 grids for the UCB1 / SW-UCB kernels. Zero pull counts
/// exercise the UCB1 play-each-arm-once warm start.
struct F64Grids {
    n: Vec<u64>,
    sum: Vec<f64>,
    mean: Vec<f64>,
    prev: Vec<i32>,
    feasible: Vec<f32>,
    t: u64,
}

fn f64_grids(b: usize, k: usize, seed: u64) -> F64Grids {
    let mut rng = Rng::new(seed ^ 0xF64);
    let mut g = F64Grids {
        n: Vec::with_capacity(b * k),
        sum: Vec::with_capacity(b * k),
        mean: Vec::with_capacity(b * k),
        prev: Vec::with_capacity(b),
        feasible: Vec::with_capacity(b * k),
        t: 1 + rng.index(1000) as u64,
    };
    for e in 0..b {
        let all_zero = rng.index(6) == 0;
        for _ in 0..k {
            g.n.push(rng.index(4) as u64);
            g.sum.push(-0.5 * (rng.index(8) + 1) as f64);
            g.mean.push(-0.5 * (rng.index(4) + 1) as f64);
            g.feasible.push(if !all_zero && rng.chance(0.8) { 1.0 } else { 0.0 });
        }
        if !all_zero {
            g.feasible[e * k + rng.index(k)] = 1.0;
        }
        g.prev.push(rng.index(k + 1) as i32 - 1);
    }
    g
}

#[test]
fn saucb_select_matches_scalar_bitwise() {
    forall_seeded(0x51D_0001, 40, Shape, |&(b, k, seed)| {
        let g = sa_grids(b, k, seed);
        let mut want = vec![0i32; b];
        saucb_select_into_with(
            Kernel::Scalar,
            &g.n,
            &g.mean,
            &g.prev,
            g.t,
            &g.feasible,
            &g.hyper,
            k,
            &mut want,
        );
        Kernel::available().into_iter().all(|kernel| {
            let mut got = vec![0i32; b];
            saucb_select_into_with(
                kernel, &g.n, &g.mean, &g.prev, g.t, &g.feasible, &g.hyper, k, &mut got,
            );
            if got != want {
                eprintln!("saucb mismatch: {} (b={b} k={k} seed={seed:#x})", kernel.name());
                return false;
            }
            true
        })
    });
}

#[test]
fn grid_update_matches_scalar_bitwise_and_freezes() {
    forall_seeded(0x51D_0002, 40, Shape, |&(b, k, seed)| {
        let g = sa_grids(b, k, seed);
        let mut rng = Rng::new(seed ^ 0x5E1);
        let sel: Vec<i32> = (0..b).map(|_| rng.index(k) as i32).collect();

        let (mut n0, mut m0, mut p0) = (g.n.clone(), g.mean.clone(), g.prev.clone());
        grid_update_batch_with(
            Kernel::Scalar,
            &mut n0,
            &mut m0,
            &mut p0,
            &sel,
            &g.reward,
            &g.active,
            k,
        );
        // Frozen rows are bitwise-invariant on the reference itself.
        for e in 0..b {
            if g.active[e] > 0.0 {
                continue;
            }
            if p0[e] != g.prev[e] {
                eprintln!("frozen prev moved (e={e}, b={b} k={k} seed={seed:#x})");
                return false;
            }
            for i in 0..k {
                let idx = e * k + i;
                if n0[idx].to_bits() != g.n[idx].to_bits()
                    || m0[idx].to_bits() != g.mean[idx].to_bits()
                {
                    eprintln!("frozen cell moved (e={e} i={i}, b={b} k={k} seed={seed:#x})");
                    return false;
                }
            }
        }

        Kernel::available().into_iter().all(|kernel| {
            let (mut n1, mut m1, mut p1) = (g.n.clone(), g.mean.clone(), g.prev.clone());
            grid_update_batch_with(
                kernel, &mut n1, &mut m1, &mut p1, &sel, &g.reward, &g.active, k,
            );
            let ok = p1 == p0
                && n1.iter().zip(&n0).all(|(a, b)| a.to_bits() == b.to_bits())
                && m1.iter().zip(&m0).all(|(a, b)| a.to_bits() == b.to_bits());
            if !ok {
                eprintln!("update mismatch: {} (b={b} k={k} seed={seed:#x})", kernel.name());
            }
            ok
        })
    });
}

#[test]
fn ucb1_select_matches_scalar_bitwise() {
    forall_seeded(0x51D_0003, 40, Shape, |&(b, k, seed)| {
        let g = f64_grids(b, k, seed);
        let alpha = 0.05;
        let mut want = vec![0i32; b];
        ucb1_select_into_with(
            Kernel::Scalar,
            &g.n,
            &g.mean,
            alpha,
            g.t,
            &g.feasible,
            k,
            &mut want,
        );
        Kernel::available().into_iter().all(|kernel| {
            let mut got = vec![0i32; b];
            ucb1_select_into_with(kernel, &g.n, &g.mean, alpha, g.t, &g.feasible, k, &mut got);
            if got != want {
                eprintln!("ucb1 mismatch: {} (b={b} k={k} seed={seed:#x})", kernel.name());
                return false;
            }
            true
        })
    });
}

#[test]
fn swucb_select_matches_scalar_bitwise() {
    forall_seeded(0x51D_0004, 40, Shape, |&(b, k, seed)| {
        let g = f64_grids(b, k, seed);
        let (alpha, lambda) = (0.05, 0.01);
        // The effective window, exactly as BatchSwUcb computes it.
        let horizon = (g.t as f64).min(64.0).max(2.0);
        let mut want = vec![0i32; b];
        swucb_select_into_with(
            Kernel::Scalar,
            &g.sum,
            &g.n,
            &g.prev,
            alpha,
            lambda,
            horizon,
            &g.feasible,
            k,
            &mut want,
        );
        Kernel::available().into_iter().all(|kernel| {
            let mut got = vec![0i32; b];
            swucb_select_into_with(
                kernel, &g.sum, &g.n, &g.prev, alpha, lambda, horizon, &g.feasible, k, &mut got,
            );
            if got != want {
                eprintln!("swucb mismatch: {} (b={b} k={k} seed={seed:#x})", kernel.name());
                return false;
            }
            true
        })
    });
}

#[test]
fn multi_step_trajectories_stay_bit_identical() {
    // A 60-step select→reward→update loop per kernel: selection history,
    // final grids, and prev must agree bit-for-bit across kernels (one
    // diverging bit anywhere would compound and show here).
    let (b, k) = (37usize, 13usize);
    let hyper = SaUcbHyper::default();
    let mut results: Vec<(Vec<i32>, Vec<u32>, Vec<u32>, Vec<i32>)> = Vec::new();
    for kernel in Kernel::available() {
        let mut n = vec![0.0f32; b * k];
        let mut mean = vec![0.0f32; b * k];
        let mut prev = vec![-1i32; b];
        let mut sel = vec![0i32; b];
        let mut hist: Vec<i32> = Vec::new();
        for t in 1..=60u64 {
            let feasible: Vec<f32> = (0..b * k)
                .map(|j| if (j + t as usize) % 11 == 0 { 0.0 } else { 1.0 })
                .collect();
            saucb_select_into_with(
                kernel, &n, &mean, &prev, t as f32, &feasible, &hyper, k, &mut sel,
            );
            let reward: Vec<f64> = sel
                .iter()
                .enumerate()
                .map(|(e, &s)| -1.0 - 0.25 * ((s as usize + e + t as usize) % 5) as f64)
                .collect();
            let active: Vec<f32> =
                (0..b).map(|e| if (e + t as usize) % 7 == 0 { 0.0 } else { 1.0 }).collect();
            grid_update_batch_with(kernel, &mut n, &mut mean, &mut prev, &sel, &reward, &active, k);
            hist.extend_from_slice(&sel);
        }
        results.push((
            hist,
            n.iter().map(|x| x.to_bits()).collect(),
            mean.iter().map(|x| x.to_bits()).collect(),
            prev,
        ));
    }
    let (h0, n0, m0, p0) = &results[0];
    for (i, (h, n, m, p)) in results.iter().enumerate().skip(1) {
        let name = Kernel::available()[i].name();
        assert_eq!(h, h0, "selection history diverged on {name}");
        assert_eq!(n, n0, "count grid diverged on {name}");
        assert_eq!(m, m0, "mean grid diverged on {name}");
        assert_eq!(p, p0, "prev diverged on {name}");
    }
}

#[test]
fn dispatch_resolution_is_consistent_with_env() {
    // This binary never calls force_kernel, so active_kernel() reflects
    // the process environment: forced scalar under the CI escape-hatch
    // leg, a chunked kernel under plain auto-detection.
    let k = active_kernel();
    assert!(k.supported());
    let forced = std::env::var("ENERGYUCB_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if forced {
        assert_eq!(k, Kernel::Scalar);
    } else if std::env::var_os("ENERGYUCB_KERNEL").is_none() {
        assert_ne!(k, Kernel::Scalar, "auto-detection must pick a chunked kernel");
    }
}
