//! Cross-validation: `fleet::native` (the batched, normalized-reward
//! Monte Carlo path) against `control::session` (the full per-node
//! simulator) on the same app/hyper/seed — the guard that keeps the
//! batched path's accounting from drifting away from the simulator it
//! abstracts.
//!
//! Two layers:
//!
//! 1. **Exact accounting** — pin both paths to a single frequency arm
//!    (StaticPolicy on the session side, a one-arm QoS mask on the fleet
//!    side). Selection is then deterministic in both, so switch counts
//!    must be *identical* and energy/steps must agree to f32 tolerance:
//!    both charge `E_step(arm) × steps + switch_energy × switches` with
//!    the same shared `SwitchCost` constants.
//! 2. **Dynamic tolerance** — run the SA-UCB controller freely in both
//!    paths; the trajectories differ (counter-noise model vs calibrated
//!    reward noise) but converged energy must land in the same band.

use energyucb::bandit::{EnergyUcb, EnergyUcbConfig, StaticPolicy};
use energyucb::control::{run_session, SessionCfg};
use energyucb::fleet::{native, FleetHyper, FleetParams, FleetState};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::Rng;
use energyucb::workload::calibration;

/// Run one fleet env restricted to `arm` (all other arms QoS-masked).
fn fleet_pinned(app_name: &str, arm: usize, seed: u64) -> (f64, f64, u64) {
    let freqs = FreqDomain::aurora();
    let app = calibration::app(app_name).unwrap();
    let mut params = FleetParams::from_apps(&[&app], &freqs, 0.01);
    for i in 0..params.k {
        params.feasible[i] = if i == arm { 1.0 } else { 0.0 };
    }
    let mut state = FleetState::fresh(1, freqs.k());
    let mut rng = Rng::new(seed);
    let steps = native::native_run(&mut state, &params, &FleetHyper::default(), &mut rng, 500_000);
    assert!(state.all_done(), "fleet env did not finish");
    (state.energy_kj(0), state.switches[0] as f64, steps)
}

#[test]
fn pinned_arm_accounting_matches_session() {
    let freqs = FreqDomain::aurora();
    for (app_name, arm) in
        [("tealeaf", 8), ("tealeaf", 0), ("clvleaf", 4), ("miniswp", 2), ("lbm", 8)]
    {
        let app = calibration::app(app_name).unwrap();
        let mut policy = StaticPolicy::new(freqs.k(), arm);
        let cfg = SessionCfg { seed: 42, ..SessionCfg::default() };
        let sess = run_session(&app, &mut policy, &cfg).metrics;

        let (fleet_kj, fleet_switches, fleet_steps) = fleet_pinned(app_name, arm, 42);

        // Identical switch counts: exactly one down-switch from the 1.6 GHz
        // default (zero when the pinned arm IS the default).
        let expected_switches = if arm == freqs.max_arm() { 0 } else { 1 };
        assert_eq!(sess.switches, expected_switches, "{app_name}/{arm}: session switches");
        assert_eq!(
            fleet_switches as u64, expected_switches,
            "{app_name}/{arm}: fleet switches"
        );

        // Energy within f32/step-quantization tolerance (< 1 %).
        let rel = (fleet_kj - sess.gpu_energy_kj).abs() / sess.gpu_energy_kj;
        assert!(
            rel < 0.01,
            "{app_name}/{arm}: fleet {fleet_kj} vs session {} ({:.3}%)",
            sess.gpu_energy_kj,
            rel * 100.0
        );

        // Step counts agree up to f32 remaining-fraction rounding, whose
        // worst-case drift grows with the step count (~n²·ε steps).
        let dstep = (fleet_steps as i64 - sess.steps as i64).abs();
        let bound = 2 + (sess.steps / 1_500) as i64;
        assert!(
            dstep <= bound,
            "{app_name}/{arm}: fleet {fleet_steps} vs session {} steps (bound {bound})",
            sess.steps
        );
    }
}

#[test]
fn dynamic_saucb_energy_within_tolerance() {
    let freqs = FreqDomain::aurora();
    for app_name in ["tealeaf", "clvleaf"] {
        let app = calibration::app(app_name).unwrap();

        let mut policy = EnergyUcb::new(freqs.k(), EnergyUcbConfig::default());
        let cfg = SessionCfg { seed: 7, ..SessionCfg::default() };
        let sess_kj = run_session(&app, &mut policy, &cfg).metrics.gpu_energy_kj;

        let params = FleetParams::from_apps(&[&app], &freqs, 0.01);
        let mut state = FleetState::fresh(1, freqs.k());
        let mut rng = Rng::new(7);
        native::native_run(&mut state, &params, &FleetHyper::default(), &mut rng, 500_000);
        assert!(state.all_done(), "{app_name}: fleet env did not finish");
        let fleet_kj = state.energy_kj(0);

        // Both controllers must beat the 1.6 GHz default and sit within a
        // 12 % band of each other (different noise models, same dynamics).
        let default_kj = app.energy_kj[freqs.max_arm()];
        assert!(sess_kj < default_kj + 0.5, "{app_name}: session {sess_kj}");
        assert!(fleet_kj < default_kj + 0.5, "{app_name}: fleet {fleet_kj}");
        let rel = (fleet_kj - sess_kj).abs() / sess_kj;
        assert!(
            rel < 0.12,
            "{app_name}: fleet {fleet_kj} vs session {sess_kj} ({:.1}%)",
            rel * 100.0
        );
    }
}
