//! Batch-controller conformance suite (EXPERIMENTS.md §Controller batch
//! contract): the single batch-native `decide`/`observe` loop must be
//! indistinguishable from every path it absorbed —
//!
//! * B = 1 through `Controller::new_batch` == `run_session`,
//!   byte-for-byte, across the shipped policies and apps;
//! * the fleet tier (`policy_run` over `FleetBackend`) == the bit-pinned
//!   `native_run` EnergyUCB trajectory, bit-for-bit;
//! * record→replay is exact at B ∈ {1, 32}, including through the
//!   counterfactual sweep tier's header-driven controller rebuild;
//! * truncated batch recordings (mid-stream cut or Drop-marked abort)
//!   are rejected with actionable errors;
//! * `sweep_replay` output is independent of `--jobs`.

use energyucb::bandit::batch::Scalar;
use energyucb::bandit::EnergyUcbConfig;
use energyucb::config::{ExperimentConfig, PolicyConfig};
use energyucb::control::{
    drive, run_session, sweep_replay, BatchOpts, Controller, EnvSpec, Recording, ReplayBackend,
    ReplayHeader, SessionCfg, SimBackend, StepSample, SweepCandidate, TelemetryBackend,
};
use energyucb::fleet::{
    build_fleet_policy, fleet_controller, native, policy_run, FleetBackend, FleetHyper,
    FleetParams, FleetState,
};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::Rng;
use energyucb::workload::calibration;

/// Every policy name the config surface ships.
const POLICIES: [&str; 10] = [
    "energyucb",
    "constrained",
    "ucb1",
    "swucb",
    "egreedy",
    "energyts",
    "rrfreq",
    "static",
    "rlpower",
    "drlcap",
];

fn policy_config(name: &str) -> PolicyConfig {
    ExperimentConfig::from_toml(&format!("[policy]\nname = \"{name}\"\n")).unwrap().policy
}

fn fleet_setup(names: &[&str], dt_s: f64) -> (FleetState, FleetParams) {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
    let refs: Vec<&_> = apps.iter().collect();
    let params = FleetParams::from_apps(&refs, &freqs, dt_s);
    (FleetState::fresh(names.len(), freqs.k()), params)
}

#[test]
fn b1_batch_drive_matches_run_session_byte_for_byte() {
    // The explicit batch construction (`new_batch` at B = 1, bridged
    // scalar policy, `SimBackend`) against the session wrapper, exact
    // float equality — for every shipped policy on two apps.
    for app_name in ["tealeaf", "clvleaf"] {
        let app = calibration::app(app_name).unwrap();
        let cfg = SessionCfg { seed: 13, max_steps: 1_000, ..SessionCfg::default() };
        for name in POLICIES {
            let pcfg = policy_config(name);
            let mut session_policy = pcfg.build(cfg.freqs.k(), cfg.seed);
            session_policy.reset();
            let session = run_session(&app, session_policy.as_mut(), &cfg);

            let mut batch_policy = pcfg.build(cfg.freqs.k(), cfg.seed);
            batch_policy.reset();
            let controller = Controller::new_batch(
                vec![EnvSpec::from_app(&app, &cfg)],
                Box::new(Scalar::new(vec![batch_policy.as_mut()])),
                &BatchOpts::from_session(&cfg),
            );
            let mut backend = SimBackend::new(&app, &cfg);
            let batch = drive(controller, &mut backend).unwrap().pop().unwrap();

            assert_eq!(batch.metrics, session.metrics, "{app_name}/{name}");
            assert_eq!(
                batch.energy_checkpoints_j, session.energy_checkpoints_j,
                "{app_name}/{name}: checkpoints"
            );
        }
    }
}

#[test]
fn fleet_drive_matches_native_run_bit_for_bit() {
    // Different roster and seed than the fleet module's own pin: the
    // drive-loop path must reproduce the bit-pinned native EnergyUCB
    // accounting on any fleet. (The policy owns its grids, so
    // `FleetState.n/mean` stay at their fresh values — every accounting
    // field must match exactly.)
    let names = ["lbm", "miniswp", "sph_exa", "tealeaf", "weather"];
    let (mut nat, params) = fleet_setup(&names, 0.01);
    let mut gen = nat.clone();
    let hyper = FleetHyper::default();

    let mut r1 = Rng::new(23);
    let nat_steps = native::native_run(&mut nat, &params, &hyper, &mut r1, 4_000);

    let mut policy = build_fleet_policy(&params, &hyper, 23);
    let mut r2 = Rng::new(23);
    let gen_steps = policy_run(&mut gen, &params, policy.as_mut(), &mut r2, 4_000);

    assert_eq!(nat_steps, gen_steps);
    assert_eq!(nat.t, gen.t);
    assert_eq!(nat.prev, gen.prev);
    assert_eq!(nat.remaining, gen.remaining);
    assert_eq!(nat.cum_energy, gen.cum_energy);
    assert_eq!(nat.cum_regret, gen.cum_regret);
    assert_eq!(nat.switches, gen.switches);
}

#[test]
fn record_then_replay_is_exact_at_b1() {
    let app = calibration::app("tealeaf").unwrap();
    let scfg = SessionCfg { seed: 17, max_steps: 1_500, ..SessionCfg::default() };
    let pcfg = policy_config("energyucb");
    let header =
        ReplayHeader::session(app.name.to_string(), Some(pcfg.clone()), scfg.clone());

    let mut buf: Vec<u8> = Vec::new();
    let live = {
        let mut policy = pcfg.build(scfg.freqs.k(), scfg.seed);
        policy.reset();
        let mut backend =
            Recording::new(SimBackend::new(&app, &scfg), &mut buf, &header).unwrap();
        let controller = Controller::new(&app, policy.as_mut(), &scfg);
        let live = drive(controller, &mut backend).unwrap().pop().unwrap();
        backend.finish().unwrap();
        live
    };

    let mut trace = ReplayBackend::from_text(std::str::from_utf8(&buf).unwrap()).unwrap();
    let mut policy = pcfg.build(scfg.freqs.k(), scfg.seed);
    policy.reset();
    let controller = Controller::new(&app, policy.as_mut(), &scfg);
    let replayed = drive(controller, &mut trace).unwrap().pop().unwrap();
    assert_eq!(replayed.metrics, live.metrics);
    assert_eq!(replayed.energy_checkpoints_j, live.energy_checkpoints_j);
}

#[test]
fn record_then_replay_is_exact_at_b32() {
    // A 32-row fleet recording replayed through the sweep tier (which
    // rebuilds the fleet controller purely from the recording's header)
    // must reproduce every environment's metrics exactly.
    let roster: Vec<&str> =
        calibration::APP_NAMES.iter().cycle().take(32).copied().collect();
    let (mut state, params) = fleet_setup(&roster, 0.01);
    let scfg = SessionCfg { seed: 31, max_steps: 800, ..SessionCfg::default() };
    let pcfg = PolicyConfig::EnergyUcb(EnergyUcbConfig::default());
    let header = ReplayHeader::fleet(
        roster.iter().map(|s| s.to_string()).collect(),
        Some(pcfg.clone()),
        scfg.clone(),
        None,
    );

    let mut buf: Vec<u8> = Vec::new();
    let mut rng = Rng::new(scfg.seed);
    let live = {
        let driver = pcfg.build_batch(32, params.k, scfg.seed);
        let controller = fleet_controller(&params, driver, scfg.max_steps);
        let mut backend = Recording::new(
            FleetBackend::new(&mut state, &params, &mut rng),
            &mut buf,
            &header,
        )
        .unwrap();
        let live = drive(controller, &mut backend).unwrap();
        backend.finish().unwrap();
        live
    };
    assert_eq!(live.len(), 32);

    let trace = ReplayBackend::from_text(std::str::from_utf8(&buf).unwrap()).unwrap();
    let out = sweep_replay(&trace, &[SweepCandidate::new(pcfg)], 2).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].results.len(), 32);
    for (e, (replayed, original)) in out[0].results.iter().zip(&live).enumerate() {
        assert_eq!(replayed.metrics, original.metrics, "env {e}");
        assert_eq!(
            replayed.energy_checkpoints_j, original.energy_checkpoints_j,
            "env {e}: checkpoints"
        );
    }
}

#[test]
fn truncated_fleet_recordings_are_rejected() {
    let roster = ["tealeaf", "clvleaf"];
    let scfg = SessionCfg { seed: 5, max_steps: 50, ..SessionCfg::default() };
    let header = ReplayHeader::fleet(
        roster.iter().map(|s| s.to_string()).collect(),
        None,
        scfg.clone(),
        None,
    );

    // (a) Mid-run abort: the tee is dropped without `finish()`, so its
    // Drop emits the truncation marker; replay refuses the log.
    let (mut state, params) = fleet_setup(&roster, 0.01);
    let mut rng = Rng::new(5);
    let mut buf: Vec<u8> = Vec::new();
    {
        let mut rec = Recording::new(
            FleetBackend::new(&mut state, &params, &mut rng),
            &mut buf,
            &header,
        )
        .unwrap();
        let sel = vec![8i32; 2];
        let mut samples = vec![StepSample::default(); 2];
        for _ in 0..5 {
            rec.apply(&sel).unwrap();
            rec.sample_into(&mut samples).unwrap();
        }
        // Dropped here, mid-run.
    }
    let text = String::from_utf8(buf).unwrap();
    let err = ReplayBackend::from_text(&text).unwrap_err().to_string();
    assert!(err.contains("truncation marker"), "{err}");
    assert!(err.contains("re-record"), "{err}");

    // (b) Mid-stream cut: a completed recording chopped before its end
    // frame (a killed process, a torn copy) must be rejected...
    let (mut state, params) = fleet_setup(&roster, 0.01);
    let mut rng = Rng::new(5);
    let mut buf: Vec<u8> = Vec::new();
    {
        let driver = build_fleet_policy(&params, &FleetHyper::default(), 5);
        let controller = fleet_controller(&params, driver, scfg.max_steps);
        let mut rec = Recording::new(
            FleetBackend::new(&mut state, &params, &mut rng),
            &mut buf,
            &header,
        )
        .unwrap();
        drive(controller, &mut rec).unwrap();
        rec.finish().unwrap();
    }
    let full = String::from_utf8(buf).unwrap();
    let lines: Vec<&str> = full.lines().collect();
    let cut = lines[..lines.len() - 1].join("\n");
    let err = ReplayBackend::from_text(&cut).unwrap_err().to_string();
    assert!(err.contains("no end frame"), "{err}");

    // ...and so must a log missing interior step frames (the end frame's
    // declared step count catches the hole).
    let mut holed: Vec<&str> = lines.clone();
    holed.remove(lines.len() - 2);
    let err = ReplayBackend::from_text(&holed.join("\n")).unwrap_err().to_string();
    assert!(err.contains("declares"), "{err}");

    // The intact log loads fine (control for the assertions above).
    assert!(ReplayBackend::from_text(&full).is_ok());
}

#[test]
fn fleet_sweep_is_independent_of_jobs() {
    // >= 3 candidates over a batch recording: candidate order and every
    // per-env metric must be identical at any worker count.
    let roster = ["tealeaf", "clvleaf", "lbm", "tealeaf", "miniswp", "clvleaf", "lbm", "tealeaf"];
    let (mut state, params) = fleet_setup(&roster, 0.01);
    let scfg = SessionCfg { seed: 41, max_steps: 400, ..SessionCfg::default() };
    let header = ReplayHeader::fleet(
        roster.iter().map(|s| s.to_string()).collect(),
        Some(PolicyConfig::EnergyUcb(EnergyUcbConfig::default())),
        scfg.clone(),
        None,
    );
    let mut buf: Vec<u8> = Vec::new();
    let mut rng = Rng::new(scfg.seed);
    {
        let driver = build_fleet_policy(&params, &FleetHyper::default(), scfg.seed);
        let controller = fleet_controller(&params, driver, scfg.max_steps);
        let mut rec = Recording::new(
            FleetBackend::new(&mut state, &params, &mut rng),
            &mut buf,
            &header,
        )
        .unwrap();
        drive(controller, &mut rec).unwrap();
        rec.finish().unwrap();
    }
    let trace = ReplayBackend::from_text(std::str::from_utf8(&buf).unwrap()).unwrap();
    let candidates = vec![
        SweepCandidate::new(policy_config("energyucb")),
        SweepCandidate::new(policy_config("ucb1")),
        SweepCandidate::new(policy_config("rrfreq")),
        SweepCandidate::new(policy_config("static")),
    ];
    let seq = sweep_replay(&trace, &candidates, 1).unwrap();
    let par = sweep_replay(&trace, &candidates, 3).unwrap();
    assert_eq!(seq.len(), 4);
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.results.len(), b.results.len());
        for (ra, rb) in a.results.iter().zip(&b.results) {
            assert_eq!(ra.metrics, rb.metrics);
            assert_eq!(ra.energy_checkpoints_j, rb.energy_checkpoints_j);
        }
    }
    // Counterfactual contract at the batch tier: the frozen stream pins
    // energy totals across candidates, while decisions differ.
    for e in 0..roster.len() {
        let kj: Vec<f64> = seq.iter().map(|o| o.results[e].metrics.gpu_energy_kj).collect();
        assert!(kj.iter().all(|&x| x == kj[0]), "env {e}: {kj:?}");
    }
    assert_ne!(
        seq[0].results[0].metrics.cumulative_regret,
        seq[2].results[0].metrics.cumulative_regret
    );
}
