//! Pinned regression: the sans-IO `Controller` + `SimBackend` + `drive`
//! composition must be byte-identical to the historical monolithic
//! `run_session` loop it replaced.
//!
//! `legacy_run_session` below is a verbatim port of the pre-refactor
//! implementation (the inline `Service` loop). Every policy family, app,
//! and configuration axis is cross-checked for exact (bit-for-bit)
//! equality of `RunMetrics`, the recorded trace, and the energy
//! checkpoints — floating-point `==`, no tolerances.

use energyucb::bandit::batch::{BatchPolicy, Scalar};
use energyucb::bandit::{
    ConstrainedEnergyUcb, EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Policy,
    RewardNormalizer, RoundRobin, SlidingWindowUcb, StaticPolicy, Ucb1,
};
use energyucb::control::{run_session, RunMetrics, SessionCfg};
use energyucb::geopm::{Control, Service};
use energyucb::sim::freq::{FreqDomain, SwitchCost};
use energyucb::sim::node::Node;
use energyucb::workload::calibration;
use energyucb::workload::model::AppModel;
use energyucb::workload::trace::{Trace, TraceStep};

/// The pre-refactor `run_session`, kept verbatim as the parity oracle.
/// (The winsorize clamp moved into `RewardNormalizer` with the same -3
/// default, so `normalize` here is the historical `normalize(..).max(-3.0)`.)
fn legacy_run_session(
    app: &AppModel,
    policy: &mut dyn Policy,
    cfg: &SessionCfg,
) -> (RunMetrics, Option<Trace>, Vec<f64>) {
    let freqs = FreqDomain::aurora().with_switch_cost(cfg.switch_cost);
    assert_eq!(policy.k(), freqs.k(), "policy arity must match frequency domain");
    let k = freqs.k();
    let node = Node::new(app.clone(), freqs.clone(), cfg.dt_s, cfg.seed);
    let mut service = Service::new(node);
    let mut normalizer = RewardNormalizer::new();
    let mut trace = cfg.record_trace.then(Trace::new);

    let mut driver = Scalar::new(vec![policy]);
    let all_feasible = vec![1.0f32; k];
    let mut sel = [0i32; 1];

    let true_rewards: Vec<f64> =
        (0..freqs.k()).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect();
    let mu_star = true_rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

    let mut cumulative_regret = 0.0;
    let mut t: u64 = 0;
    let mut checkpoints = vec![0.0f64; cfg.checkpoints];
    let mut next_cp = 0usize;
    let mut cum_true_energy_j = 0.0;
    let mut final_completed = 0.0;

    while !service.done() && t < cfg.max_steps {
        t += 1;
        driver.select_into(t, &all_feasible, &mut sel);
        let arm = sel[0] as usize;
        service.write(Control::GpuFrequency(arm)).expect("valid arm");
        let sample = service.sample().expect("not done");
        let obs = sample.obs;

        let raw = cfg.reward_form.raw(obs.gpu_energy_j, obs.core_util, obs.uncore_util);
        let reward = normalizer.normalize(raw);
        driver.update_batch(&sel, &[reward], &[obs.progress], &[1.0]);

        cumulative_regret += mu_star - true_rewards[arm];
        cum_true_energy_j += obs.true_gpu_energy_j;

        let completed = 1.0 - obs.remaining;
        final_completed = completed;
        while next_cp < cfg.checkpoints
            && completed >= (next_cp + 1) as f64 / cfg.checkpoints as f64 - 1e-12
        {
            checkpoints[next_cp] = cum_true_energy_j;
            next_cp += 1;
        }

        if let Some(tr) = trace.as_mut() {
            tr.push(TraceStep {
                t,
                arm,
                reward,
                energy_j: obs.true_gpu_energy_j,
                regret: mu_star - true_rewards[arm],
                switched: sample.switched,
            });
        }
    }
    for cp in checkpoints.iter_mut().skip(next_cp) {
        *cp = cum_true_energy_j;
    }

    let totals = service.totals();
    let metrics = RunMetrics {
        app: app.name.to_string(),
        policy: driver.name(),
        gpu_energy_kj: totals.gpu_energy_kj,
        exec_time_s: totals.exec_time_s,
        switches: totals.switches,
        switch_energy_j: totals.switch_energy_j,
        switch_time_s: totals.switch_time_s,
        cumulative_regret,
        steps: t,
        completed: final_completed.clamp(0.0, 1.0),
        qos_violation_frac: None,
    };
    (metrics, trace, checkpoints)
}

/// Exact cross-check of one (policy-pair, app, cfg) case.
fn assert_parity(
    label: &str,
    app: &AppModel,
    legacy_policy: &mut dyn Policy,
    new_policy: &mut dyn Policy,
    cfg: &SessionCfg,
) {
    let (legacy_metrics, legacy_trace, legacy_cps) = legacy_run_session(app, legacy_policy, cfg);
    let new = run_session(app, new_policy, cfg);
    assert_eq!(new.metrics, legacy_metrics, "{label}: metrics diverged");
    assert_eq!(new.energy_checkpoints_j, legacy_cps, "{label}: checkpoints diverged");
    match (&new.trace, &legacy_trace) {
        (None, None) => {}
        (Some(a), Some(b)) => assert_eq!(a.steps(), b.steps(), "{label}: trace diverged"),
        _ => panic!("{label}: trace presence diverged"),
    }
}

/// Two independent instances of each policy configuration (one per
/// implementation under test).
fn policy_pairs() -> Vec<(&'static str, Box<dyn Policy>, Box<dyn Policy>)> {
    fn pair<P: Policy + 'static>(
        name: &'static str,
        mk: impl Fn() -> P,
    ) -> (&'static str, Box<dyn Policy>, Box<dyn Policy>) {
        (name, Box::new(mk()), Box::new(mk()))
    }
    vec![
        pair("static", || StaticPolicy::new(9, 8)),
        pair("rrfreq", || RoundRobin::new(9)),
        pair("energyucb", || EnergyUcb::new(9, EnergyUcbConfig::default())),
        pair("constrained", || ConstrainedEnergyUcb::new(9, EnergyUcbConfig::default(), 0.05)),
        pair("ucb1", || Ucb1::new(9, 0.05)),
        pair("swucb", || SlidingWindowUcb::new(9, 0.05, 0.01, 500)),
        pair("egreedy", || EpsilonGreedy::new(9, 0.1, 20.0, 7)),
        pair("energyts", || EnergyTs::default_for(9, 7)),
    ]
}

#[test]
fn rebuilt_session_is_byte_identical_across_policies() {
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg { seed: 3, max_steps: 1_500, ..SessionCfg::default() };
    for (name, mut legacy, mut new) in policy_pairs() {
        assert_parity(name, &app, legacy.as_mut(), new.as_mut(), &cfg);
    }
}

#[test]
fn rebuilt_session_is_byte_identical_on_full_runs() {
    // Uncapped runs to job completion, across apps.
    for app_name in ["tealeaf", "clvleaf"] {
        let app = calibration::app(app_name).unwrap();
        let cfg = SessionCfg { seed: 11, ..SessionCfg::default() };
        let mut a = EnergyUcb::new(9, EnergyUcbConfig::default());
        let mut b = EnergyUcb::new(9, EnergyUcbConfig::default());
        assert_parity(app_name, &app, &mut a, &mut b, &cfg);
    }
}

#[test]
fn rebuilt_session_is_byte_identical_with_trace_and_custom_cost() {
    let app = calibration::app("clvleaf").unwrap();
    let cfg = SessionCfg {
        seed: 42,
        record_trace: true,
        switch_cost: SwitchCost { latency_s: 450e-6, energy_j: 0.9 },
        ..SessionCfg::default()
    };
    let mut a = RoundRobin::new(9);
    let mut b = RoundRobin::new(9);
    let (legacy_metrics, legacy_trace, _) = legacy_run_session(&app, &mut a, &cfg);
    let new = run_session(&app, &mut b, &cfg);
    assert_eq!(new.metrics, legacy_metrics);
    // Full per-step trace equality, bit-for-bit.
    assert_eq!(new.trace.unwrap().steps(), legacy_trace.unwrap().steps());
}

#[test]
fn rebuilt_session_is_byte_identical_across_reward_forms() {
    use energyucb::bandit::RewardForm;
    let app = calibration::app("tealeaf").unwrap();
    for form in
        [RewardForm::EnergyRatio, RewardForm::EnergySquaredRatio, RewardForm::EnergyRatioSquared]
    {
        let cfg =
            SessionCfg { seed: 5, max_steps: 800, reward_form: form, ..SessionCfg::default() };
        let mut a = EnergyUcb::new(9, EnergyUcbConfig::default());
        let mut b = EnergyUcb::new(9, EnergyUcbConfig::default());
        assert_parity(form.name(), &app, &mut a, &mut b, &cfg);
    }
}
