//! End-to-end tests of the live-hardware subsystem: the real controller
//! driving [`HwBackend`] over the fault-scripted [`MockDriver`], and the
//! record→replay / sweep contract on hardware telemetry traces.
//!
//! Every fault class from the mock's matrix (reject, clamp, stale
//! counter, NaN counter, device loss) is driven through `drive` here —
//! the controller must survive all of them, the watchdog must degrade a
//! dead device instead of crashing the run, and clocks must be released
//! on every exit path including panic unwinds.

use std::sync::{Arc, Mutex};

use energyucb::bandit::EnergyUcbConfig;
use energyucb::config::PolicyConfig;
use energyucb::control::{
    drive, sweep_replay, Controller, Recording, ReplayBackend, ReplayHeader, RunMetrics,
    SessionCfg, SweepCandidate, TelemetryBackend,
};
use energyucb::fleet::{fleet_controller, FleetParams};
use energyucb::hw::{parse_fault, HwBackend, HwTuning, MockDriver, MockHandle};
use energyucb::workload::calibration;
use energyucb::workload::model::AppModel;

/// A clonable in-memory JSONL sink, so record→replay needs no disk.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    fn text(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

impl std::io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn scfg(max_steps: u64) -> SessionCfg {
    SessionCfg { seed: 7, max_steps, ..SessionCfg::default() }
}

fn app() -> AppModel {
    calibration::app("tealeaf").unwrap()
}

/// Calibrated mock + backend, returning a handle into the driver state.
fn mock_backend(
    faults: &[&str],
    devices: usize,
    cfg: &SessionCfg,
    tuning: HwTuning,
) -> (HwBackend, MockHandle) {
    let parsed = faults.iter().map(|s| parse_fault(s).unwrap()).collect();
    let driver = MockDriver::calibrated(&app(), &cfg.domain(), devices, cfg.dt_s, cfg.seed)
        .with_faults(parsed);
    let handle = driver.handle();
    let backend = HwBackend::new(Box::new(driver), cfg, tuning).unwrap();
    (backend, handle)
}

fn policy_cfg() -> PolicyConfig {
    PolicyConfig::EnergyUcb(EnergyUcbConfig::default())
}

#[test]
fn controller_survives_the_full_fault_matrix() {
    // Reject on an early clock request, clamp on the next, then a stale
    // and a NaN counter read: the drive loop must run to its step budget
    // with the rails absorbing every fault.
    let cfg = scfg(120);
    let (mut backend, _h) = mock_backend(
        &["reject@1", "clamp@2", "stale@4", "nan@6"],
        1,
        &cfg,
        HwTuning::default(),
    );
    let mut policy = policy_cfg().build(9, cfg.seed);
    policy.reset();
    let a = app();
    let controller = Controller::new(&a, policy.as_mut(), &cfg);
    let results = drive(controller, &mut backend).unwrap();
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].metrics.steps, 120);
    assert!(results[0].metrics.gpu_energy_kj > 0.0);
    // Optimistic-init UCB revisits arms throughout warmup, so the
    // scripted apply faults (calls 1 and 2) both fired.
    assert!(backend.driver_errors() >= 3, "reject + stale + nan not all observed");
    assert!(backend.clamped() >= 1, "clamp not observed");
    // Isolated faults interleaved with good calls never reach the
    // watchdog's consecutive-error threshold.
    assert!(!backend.degraded(0));
    assert_eq!(backend.watchdog_trips(), 0);
}

#[test]
fn device_loss_degrades_one_row_and_the_run_survives() {
    // Device 1 falls off the bus at its 5th read and stays gone; device 0
    // is healthy. The watchdog must freeze row 1 only, and the batch run
    // must still produce a result for every row.
    let cfg = scfg(40);
    let tuning = HwTuning { min_dwell_steps: 1, watchdog_errors: 2 };
    let (mut backend, _h) = mock_backend(&["lost@5/1"], 2, &cfg, tuning);
    let freqs = cfg.domain();
    let apps = [app(), app()];
    let refs: Vec<&AppModel> = apps.iter().collect();
    let params = FleetParams::from_apps(&refs, &freqs, cfg.dt_s);
    let driver = policy_cfg().build_batch(2, 9, cfg.seed);
    let controller = fleet_controller(&params, driver, cfg.max_steps);
    let results = drive(controller, &mut backend).unwrap();
    assert_eq!(results.len(), 2);
    assert!(!backend.degraded(0), "healthy device must stay live");
    assert!(backend.degraded(1), "lost device must degrade");
    assert_eq!(backend.watchdog_trips(), 1);
    // Two consecutive read errors tripped it; after that the row is
    // frozen and the driver is never polled for it again.
    assert_eq!(backend.driver_errors(), 2);
    // The healthy row kept measuring; the dead row's totals froze at the
    // last good read.
    let totals = backend.totals();
    assert!(totals[0].exec_time_s > totals[1].exec_time_s);
}

#[test]
fn clocks_unlock_on_drop_after_a_drive() {
    let cfg = scfg(5);
    let (mut backend, h) = mock_backend(&[], 1, &cfg, HwTuning::default());
    let mut policy = PolicyConfig::Static { arm: 0 }.build(9, cfg.seed);
    policy.reset();
    let a = app();
    let controller = Controller::new(&a, policy.as_mut(), &cfg);
    drive(controller, &mut backend).unwrap();
    // The static policy locked the lowest arm on its first decision.
    assert_eq!(h.locked_mhz(0), Some(800));
    drop(backend);
    assert_eq!(h.locked_mhz(0), None, "drop must release the clock lock");
    assert_eq!(h.resets(0), 1);
}

#[test]
fn clocks_unlock_when_the_policy_panics_mid_drive() {
    // PanicAfter is the config-buildable chaos policy: it decides
    // normally for `after` steps, then panics inside the drive loop. The
    // unwind must still release the device clocks via HwBackend's Drop.
    let cfg = scfg(100);
    let (mut backend, h) = mock_backend(&[], 1, &cfg, HwTuning::default());
    backend.apply(&[0]).unwrap(); // hold a lock before the crash
    assert_eq!(h.locked_mhz(0), Some(800));
    let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        let mut policy = PolicyConfig::PanicAfter { after: 10 }.build(9, cfg.seed);
        policy.reset();
        let a = app();
        let controller = Controller::new(&a, policy.as_mut(), &cfg);
        let _ = drive(controller, &mut backend);
    }));
    assert!(unwound.is_err(), "the chaos policy must have panicked");
    assert_eq!(h.locked_mhz(0), None, "unwind must release the clock lock");
    assert!(h.resets(0) >= 1);
}

/// Record a B = 1 mock-hardware session (with mid-run faults) through
/// the standard Recording tee; returns (trace text, live metrics).
fn record_session_trace(cfg: &SessionCfg) -> (String, RunMetrics) {
    let (backend, _h) = mock_backend(&["stale@3", "nan@5"], 1, cfg, HwTuning::default());
    let buf = SharedBuf::default();
    let header = ReplayHeader::session("tealeaf".into(), Some(policy_cfg()), cfg.clone());
    let mut rec = Recording::new(backend, buf.clone(), &header).unwrap();
    let mut policy = policy_cfg().build(9, cfg.seed);
    policy.reset();
    let a = app();
    let controller = Controller::new(&a, policy.as_mut(), cfg);
    let mut results = drive(controller, &mut rec).unwrap();
    rec.finish().unwrap();
    (buf.text(), results.pop().unwrap().metrics)
}

#[test]
fn recorded_mock_session_replays_with_identical_metrics() {
    let cfg = scfg(300);
    let (text, live) = record_session_trace(&cfg);
    assert!(text.contains("\"step\""), "trace must use the standard grammar:\n{text}");
    let mut backend = ReplayBackend::from_text(&text).unwrap();
    let header = backend.header().clone();
    assert_eq!(header.app, "tealeaf");
    let mut policy =
        header.policy.clone().unwrap().build(header.session.freqs.k(), header.session.seed);
    policy.reset();
    let a = app();
    let controller = Controller::new(&a, policy.as_mut(), &header.session);
    let replayed = drive(controller, &mut backend).unwrap().pop().unwrap().metrics;
    assert_eq!(live, replayed, "replay must reproduce the hardware run exactly");
}

#[test]
fn sweep_over_a_mock_recording_matches_direct_replay() {
    let cfg = scfg(300);
    let (text, live) = record_session_trace(&cfg);
    let trace = ReplayBackend::from_text(&text).unwrap();
    let outcomes = sweep_replay(&trace, &[SweepCandidate::new(policy_cfg())], 1).unwrap();
    assert_eq!(outcomes.len(), 1);
    assert_eq!(outcomes[0].results.len(), 1);
    assert_eq!(outcomes[0].results[0].metrics, live);
}

#[test]
fn multi_device_recording_sweeps_byte_identically() {
    // Three mock GPUs, one of which dies mid-run: the recorded fleet-
    // grammar trace must drive `sweep --replay` to the exact metrics the
    // live run produced, per device.
    let cfg = scfg(60);
    let b = 3;
    let (backend, _h) =
        mock_backend(&["lost@30/2"], b, &cfg, HwTuning { min_dwell_steps: 2, watchdog_errors: 2 });
    let freqs = cfg.domain();
    let apps = [app(), app(), app()];
    let refs: Vec<&AppModel> = apps.iter().collect();
    let params = FleetParams::from_apps(&refs, &freqs, cfg.dt_s);
    let driver = policy_cfg().build_batch(b, 9, cfg.seed);
    let controller = fleet_controller(&params, driver, cfg.max_steps);
    let buf = SharedBuf::default();
    let header = ReplayHeader::fleet(
        vec!["tealeaf".into(); b],
        Some(policy_cfg()),
        cfg.clone(),
        None,
    );
    let mut rec = Recording::new(backend, buf.clone(), &header).unwrap();
    let live: Vec<RunMetrics> =
        drive(controller, &mut rec).unwrap().into_iter().map(|r| r.metrics).collect();
    rec.finish().unwrap();
    assert_eq!(live.len(), b);

    let trace = ReplayBackend::from_text(&buf.text()).unwrap();
    let outcomes = sweep_replay(&trace, &[SweepCandidate::new(policy_cfg())], 1).unwrap();
    let swept: Vec<RunMetrics> = outcomes[0].results.iter().map(|r| r.metrics.clone()).collect();
    assert_eq!(live, swept, "sweep must reproduce the live multi-device run exactly");
}
