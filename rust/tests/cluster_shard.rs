//! Sharded-cluster integration: worker subprocesses receive their
//! assignment batches *only* via the framed-JSONL wire protocol, and the
//! merged report is byte-identical across `--shards` ∈ {1, N} and vs the
//! in-process pool — the extended determinism contract of
//! EXPERIMENTS.md §Cluster.

use std::path::PathBuf;
use std::process::Command;

use energyucb::cluster::{ClusterConfig, InProcess, Leader, ScenarioSchedule, Subprocess};
use energyucb::control::SessionCfg;

/// The cargo-built CLI (leader and worker are the same binary). Tests
/// must pass it explicitly: `current_exe()` inside a test harness would
/// re-enter the *test* binary, not `energyucb`.
const BIN: &str = env!("CARGO_BIN_EXE_energyucb");

/// Short sessions keep the library-level cases cheap; the CLI-level
/// acceptance test below runs full-length sessions.
fn test_cfg(jobs: usize) -> ClusterConfig {
    ClusterConfig {
        jobs,
        heartbeat_steps: 100,
        session: SessionCfg { max_steps: 400, ..SessionCfg::default() },
        ..ClusterConfig::default()
    }
}

/// Every scenario preset, through real worker subprocesses, at several
/// shard counts — all byte-identical to the unsharded in-process run.
#[test]
fn subprocess_shards_match_the_in_process_pool_byte_for_byte() {
    for scenario in ["uniform", "mixed", "staggered", "hetero"] {
        let schedule = ScenarioSchedule::preset(scenario, 21).unwrap();
        let mut assignments = schedule.assignments(9).unwrap();
        // Scale staggered budgets down 10x (150-600 steps), as the
        // property suite does, to bound test wall-clock.
        for a in &mut assignments {
            a.max_steps = a.max_steps.map(|m| (m / 10).max(1));
        }
        let leader = Leader::new(test_cfg(2));
        let baseline = leader.run(&assignments).unwrap();
        let subprocess = Subprocess::with_program(BIN);
        for shards in [1, 3, 9] {
            let report = leader.run_sharded(&assignments, shards, &subprocess).unwrap();
            assert_eq!(report.render(), baseline.render(), "{scenario} --shards {shards}");
            assert_eq!(
                report.to_csv().render(),
                baseline.to_csv().render(),
                "{scenario} --shards {shards}"
            );
        }
        // The in-process transport honors the same contract at any
        // shard count (shards > nodes collapses to one node per shard).
        for shards in [2, 16] {
            let report = leader.run_sharded(&assignments, shards, &InProcess).unwrap();
            assert_eq!(report.render(), baseline.render(), "{scenario} in-process {shards}");
        }
    }
}

/// Worker-side validation surfaces as a leader error, not a hang or a
/// panic: the worker answers with an `error` frame and exit code 1.
#[test]
fn worker_failures_become_leader_errors() {
    let leader = Leader::new(test_cfg(1));
    // Leader-side validation catches bad batches before any spawn.
    let bad = vec![energyucb::cluster::NodeAssignment::new(0, "not-an-app", 1)];
    assert!(leader.run_sharded(&bad, 1, &Subprocess::with_program(BIN)).is_err());
    // A missing worker binary is a clean spawn error.
    let gone = Subprocess::with_program("/nonexistent/energyucb");
    let ok = ScenarioSchedule::preset("uniform", 3).unwrap().assignments(2).unwrap();
    assert!(leader.run_sharded(&ok, 2, &gone).is_err());
}

/// Malformed stdin produces an `error` frame and a non-zero exit — the
/// worker never panics on wire noise.
#[test]
fn cluster_worker_rejects_malformed_stdin_with_an_error_frame() {
    use std::io::Write;
    use std::process::Stdio;

    for bad_input in [
        "{\"frame\":\"assign\"\n",           // truncated JSON
        "{\"frame\":\"event\",\"payload\":{}}\n", // leader-only frame
        "{\"frame\":\"run\"}\n",             // run before config
        "",                                       // empty stream
    ] {
        let mut child = Command::new(BIN)
            .arg("cluster-worker")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        child.stdin.take().unwrap().write_all(bad_input.as_bytes()).unwrap();
        let out = child.wait_with_output().unwrap();
        assert!(!out.status.success(), "input {bad_input:?} should fail");
        let text = String::from_utf8(out.stdout).unwrap();
        assert!(text.contains("\"frame\":\"error\""), "input {bad_input:?} → {text}");
    }
}

/// The acceptance bar: `energyucb cluster --scenario mixed --nodes 24`
/// produces a byte-identical report and CSV for `--shards 1`, `--shards
/// 3`, and the in-process pool, end to end through the real CLI.
#[test]
fn cli_mixed_24_nodes_is_byte_identical_across_shard_counts() {
    let dir = std::env::temp_dir().join(format!("energyucb_shard_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |shards: Option<usize>| -> (String, String) {
        let csv: PathBuf = dir.join(match shards {
            Some(s) => format!("shards{s}.csv"),
            None => "pool.csv".to_string(),
        });
        let mut cmd = Command::new(BIN);
        cmd.args(["cluster", "--scenario", "mixed", "--nodes", "24", "--seed", "7", "--jobs", "2", "--csv"])
            .arg(&csv);
        if let Some(s) = shards {
            cmd.args(["--shards", &s.to_string()]);
        }
        let out = cmd.output().expect("spawn energyucb");
        assert!(
            out.status.success(),
            "exit {:?}\nstderr: {}",
            out.status,
            String::from_utf8_lossy(&out.stderr)
        );
        (
            String::from_utf8(out.stdout).unwrap(),
            std::fs::read_to_string(&csv).unwrap(),
        )
    };
    let (pool_text, pool_csv) = run(None);
    assert!(!pool_text.is_empty() && !pool_csv.is_empty());
    for shards in [1, 3] {
        let (text, csv) = run(Some(shards));
        assert_eq!(text, pool_text, "--shards {shards} stdout differs from the pool");
        assert_eq!(csv, pool_csv, "--shards {shards} csv differs from the pool");
    }
    std::fs::remove_dir_all(&dir).ok();
}
