//! End-to-end properties of the cluster engine: scenario reports are
//! byte-identical at any worker count, per-app stats equal a serial
//! reference merge, the heartbeat stream is scheduling-independent, and
//! the work-stealing leader reproduces the pre-refactor wave numbers.

use std::collections::BTreeMap;

use energyucb::cluster::{ClusterConfig, Leader, NodeAssignment, ScenarioSchedule};
use energyucb::config::PolicyConfig;
use energyucb::control::{run_session, SessionCfg};
use energyucb::exec::available_jobs;
use energyucb::testutil::forall_seeded;
use energyucb::testutil::gens::{OneOf, Pair, USize};
use energyucb::util::stats::Welford;
use energyucb::workload::calibration;

/// Short sessions keep the property cases cheap (the cap is itself part of
/// the scenario surface: staggered budgets below the cap still apply).
fn test_cluster_config(jobs: usize) -> ClusterConfig {
    ClusterConfig {
        jobs,
        heartbeat_steps: 100,
        session: SessionCfg { max_steps: 400, ..SessionCfg::default() },
        ..ClusterConfig::default()
    }
}

/// Serial reference: run every assignment's session directly (no pool, no
/// channel) and merge per-app energies in node order.
fn reference_per_app(
    assignments: &[NodeAssignment],
    cfg: &ClusterConfig,
) -> BTreeMap<String, (u64, f64, f64)> {
    let mut acc: BTreeMap<String, Welford> = BTreeMap::new();
    let mut ordered = assignments.to_vec();
    ordered.sort_by_key(|a| a.node);
    for a in &ordered {
        let app = calibration::app(&a.app).unwrap();
        let scfg = SessionCfg {
            seed: a.seed,
            max_steps: a.max_steps.unwrap_or(cfg.session.max_steps),
            switch_cost: a.switch_cost.unwrap_or(cfg.session.switch_cost),
            ..cfg.session.clone()
        };
        let mut policy = a.policy.clone().unwrap_or_else(|| cfg.policy.clone()).build(9, a.seed);
        let result = run_session(&app, policy.as_mut(), &scfg);
        acc.entry(a.app.clone()).or_default().push(result.metrics.gpu_energy_kj);
    }
    acc.into_iter().map(|(k, w)| (k, (w.count(), w.mean(), w.sample_std()))).collect()
}

#[test]
fn any_scenario_report_is_byte_identical_across_jobs() {
    let scenarios = OneOf(vec!["uniform", "mixed", "staggered", "hetero"]);
    let sizes = USize { lo: 3, hi: 6 };
    forall_seeded(0xC1057E4, 5, Pair(scenarios, sizes), |(name, nodes)| {
        let schedule = ScenarioSchedule::preset(name, 40 + *nodes as u64).unwrap();
        let mut assignments = schedule.assignments(*nodes).unwrap();
        // Scale staggered budgets down 10x (150–600 steps): keeps the
        // mixed-duration structure while bounding deep PROPTEST_CASES runs.
        for a in &mut assignments {
            a.max_steps = a.max_steps.map(|m| (m / 10).max(1));
        }

        let serial = Leader::new(test_cluster_config(1)).run(&assignments).unwrap();
        let serial_text = serial.render();
        let serial_csv = serial.to_csv().render();

        // Byte-identical text and CSV at every worker count.
        for jobs in [2, available_jobs()] {
            let report = Leader::new(test_cluster_config(jobs)).run(&assignments).unwrap();
            if report.render() != serial_text || report.to_csv().render() != serial_csv {
                return false;
            }
        }

        // Per-app Welford stats equal the serial reference merge exactly.
        let reference = reference_per_app(&assignments, &test_cluster_config(1));
        serial.per_app == reference
    });
}

#[test]
fn heartbeat_stream_is_intact_under_work_stealing() {
    // With the session cap at 400 steps and heartbeats every 100, every
    // node emits exactly 4 beats regardless of which worker runs it.
    let schedule = ScenarioSchedule::preset("uniform", 77).unwrap();
    let assignments = schedule.assignments(6).unwrap();
    let report = Leader::new(test_cluster_config(available_jobs())).run(&assignments).unwrap();
    assert!(report.nodes.iter().all(|r| r.metrics.steps == 400));
    assert_eq!(report.heartbeats, 6 * 4, "heartbeat stream lost events under stealing");

    // Mixed-duration fleet: the total is the per-node sum, still exact.
    let schedule = ScenarioSchedule::preset("staggered", 78).unwrap();
    let assignments = schedule.assignments(5).unwrap();
    let report = Leader::new(test_cluster_config(available_jobs())).run(&assignments).unwrap();
    let expected: u64 =
        report.nodes.iter().map(|r| (r.metrics.steps / 100).clamp(1, 50)).sum();
    assert_eq!(report.heartbeats, expected);
    // Every node is visible in the stream: >= 1 beat each, even when a
    // staggered budget is shorter than one heartbeat interval.
    assert!(report.heartbeats >= report.nodes.len() as u64);
}

#[test]
fn round_robin_matches_pre_refactor_wave_numbers() {
    // Same seeds, same totals: the work-stealing leader, the legacy wave
    // scheduler, and a direct serial loop (the pre-refactor semantics:
    // one session per node, seed = seed0 + node, summed in node order)
    // must agree to the bit.
    let cfg = test_cluster_config(3);
    let leader = Leader::new(cfg.clone());
    let assignments = Leader::assign_round_robin(&["tealeaf", "clvleaf"], 6, 42);

    let stealing = leader.run(&assignments).unwrap();
    let waves = leader.run_waves(&assignments).unwrap();
    assert_eq!(stealing.render(), waves.render());
    assert_eq!(stealing.to_csv().render(), waves.to_csv().render());
    assert_eq!(stealing.heartbeats, waves.heartbeats);

    let mut serial_total = 0.0;
    for a in &assignments {
        let app = calibration::app(&a.app).unwrap();
        let mut policy = cfg.policy.build(9, a.seed);
        let scfg = SessionCfg { seed: a.seed, ..cfg.session.clone() };
        serial_total += run_session(&app, policy.as_mut(), &scfg).metrics.gpu_energy_kj;
    }
    assert_eq!(stealing.total_energy_kj, serial_total);
}

#[test]
fn per_app_policy_overrides_reach_the_nodes() {
    let mut schedule = ScenarioSchedule::round_robin(&["lbm", "tealeaf"], 9);
    schedule.slots[0].policy = Some(PolicyConfig::Static { arm: 7 });
    let assignments = schedule.assignments(4).unwrap();
    let report = Leader::new(test_cluster_config(2)).run(&assignments).unwrap();
    assert_eq!(report.nodes[0].metrics.policy, "Static[arm 7]");
    assert_eq!(report.nodes[2].metrics.policy, "Static[arm 7]");
    assert_ne!(report.nodes[1].metrics.policy, "Static[arm 7]");
}
