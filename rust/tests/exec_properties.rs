//! Property tests for the deterministic executor (`exec`): grid indexing
//! roundtrips for arbitrary shapes, and `run_indexed` scheduling
//! invariants on a side-effect-counting workload.

use std::sync::atomic::{AtomicUsize, Ordering};

use energyucb::exec::{available_jobs, cell_rng, run_indexed, CellGrid};
use energyucb::testutil::forall;
use energyucb::testutil::gens::{OneOf, Pair, USize, VecUSize};

#[test]
fn grid_pack_unpack_roundtrips_for_arbitrary_shapes() {
    // Shapes come in as [rows, cols, reps] vectors (per-element shrinking
    // finds the minimal failing axis if the indexing math regresses).
    forall(150, VecUSize { lo: 1, hi: 7, min_len: 3, max_len: 3 }, |shape| {
        let g = CellGrid::new(shape[0], shape[1], shape[2]);
        (0..g.len()).all(|cell| {
            let (row, col, rep) = g.unpack(cell);
            row < g.rows
                && col < g.cols
                && rep < g.reps
                && g.pack(row, col, rep) == cell
                && g.group(row, col) == cell / g.reps
        })
    });
}

#[test]
fn grid_pack_is_a_bijection() {
    forall(100, VecUSize { lo: 1, hi: 6, min_len: 3, max_len: 3 }, |shape| {
        let g = CellGrid::new(shape[0], shape[1], shape[2]);
        let mut seen = vec![false; g.len()];
        for row in 0..g.rows {
            for col in 0..g.cols {
                for rep in 0..g.reps {
                    let cell = g.pack(row, col, rep);
                    if cell >= g.len() || seen[cell] {
                        return false;
                    }
                    seen[cell] = true;
                }
            }
        }
        seen.into_iter().all(|s| s)
    });
}

#[test]
fn run_indexed_is_index_ordered_and_identical_across_jobs() {
    // A cell function with observable side effects: counts invocations and
    // derives its value from the order-independent cell RNG.
    let calls = AtomicUsize::new(0);
    let cell = |i: usize| {
        calls.fetch_add(1, Ordering::Relaxed);
        let mut rng = cell_rng(0xC1u64, i as u64);
        (i, rng.next_u64())
    };

    let n = 53;
    let reference: Vec<(usize, u64)> = run_indexed(1, n, cell);
    assert_eq!(calls.swap(0, Ordering::Relaxed), n, "sequential path skipped cells");
    assert!(reference.iter().enumerate().all(|(i, (j, _))| i == *j), "not index-ordered");

    for jobs in [2, 7, available_jobs()] {
        let out = run_indexed(jobs, n, cell);
        // Exactly one evaluation per cell — work stealing must neither
        // drop nor double-run cells.
        assert_eq!(calls.swap(0, Ordering::Relaxed), n, "jobs={jobs}: wrong call count");
        assert_eq!(out, reference, "jobs={jobs}: output differs from sequential");
    }
}

#[test]
fn run_indexed_property_all_job_counts_agree() {
    // Property over (n, jobs): result equals the inline map at any size
    // and worker count, including n = 0 and jobs > n.
    let sizes = USize { lo: 0, hi: 40 };
    let jobs = OneOf(vec![1usize, 2, 7, available_jobs()]);
    forall(60, Pair(sizes, jobs), |(n, jobs)| {
        let expect: Vec<u64> = (0..*n).map(|i| cell_rng(7, i as u64).next_u64()).collect();
        let got = run_indexed(*jobs, *n, |i| cell_rng(7, i as u64).next_u64());
        got == expect
    });
}
