//! Wire-codec properties: every cluster message round-trips through the
//! JSONL frame grammar *exactly* (the sharded determinism contract rests
//! on this), and the hand-rolled JSON reader rejects truncated and
//! malformed frames with errors — never panics, never stack-overflows.

use energyucb::bandit::energyucb::{EnergyUcbConfig, InitStrategy};
use energyucb::cluster::{Frame, NodeAssignment, NodeResult, WireCodec, WorkerEvent};
use energyucb::config::PolicyConfig;
use energyucb::control::{RunMetrics, SessionCfg};
use energyucb::sim::freq::SwitchCost;
use energyucb::testutil::{forall_seeded, Gen};
use energyucb::util::io::Json;
use energyucb::util::Rng;

/// Strings that stress JSON escaping: quotes, backslashes, control
/// characters, CSV-hostile separators, and multi-byte UTF-8.
fn gen_name(rng: &mut Rng) -> String {
    const NAMES: [&str; 7] = [
        "EnergyUCB[a 0.035]",
        "quote\"inside",
        "back\\slash",
        "multi\nline\r\twhitespace",
        "comma,separated",
        "unicodé ☃ 中文 😀",
        "",
    ];
    NAMES[rng.index(NAMES.len())].to_string()
}

fn gen_ucb(rng: &mut Rng) -> EnergyUcbConfig {
    EnergyUcbConfig {
        alpha: rng.uniform_range(0.0, 1.0),
        lambda: rng.uniform_range(0.0, 0.1),
        mu_init: rng.uniform_range(-1.0, 1.0),
        prior_n: rng.uniform_range(0.0, 5.0),
        init: if rng.chance(0.5) {
            InitStrategy::Optimistic
        } else {
            InitStrategy::WarmupRoundRobin
        },
        discount: rng.uniform_range(0.5, 1.0),
    }
}

struct PolicyGen;

impl Gen for PolicyGen {
    type Value = PolicyConfig;

    fn generate(&self, rng: &mut Rng) -> PolicyConfig {
        match rng.index(12) {
            0 => PolicyConfig::EnergyUcb(gen_ucb(rng)),
            1 => PolicyConfig::ConstrainedEnergyUcb { ucb: gen_ucb(rng), delta: rng.uniform() },
            2 => PolicyConfig::Ucb1 { alpha: rng.uniform() },
            3 => PolicyConfig::EpsilonGreedy {
                eps0: rng.uniform(),
                decay_c: rng.uniform_range(1.0, 50.0),
            },
            4 => PolicyConfig::EnergyTs,
            5 => PolicyConfig::RoundRobin,
            6 => PolicyConfig::Static { arm: rng.index(9) },
            7 => PolicyConfig::RlPower,
            8 => PolicyConfig::SwUcb {
                alpha: rng.uniform(),
                lambda: rng.uniform_range(0.0, 0.1),
                window: 1 + rng.index(2_000),
            },
            9 => PolicyConfig::LinUcb {
                alpha: rng.uniform_range(0.0, 2.0),
                ridge: rng.uniform_range(0.1, 5.0),
            },
            10 => PolicyConfig::CLinUcb {
                alpha: rng.uniform_range(0.0, 2.0),
                ridge: rng.uniform_range(0.1, 5.0),
                delta: rng.uniform(),
            },
            _ => PolicyConfig::DrlCap {
                mode: ["pretrain", "online", "cross"][rng.index(3)].to_string(),
            },
        }
    }
}

struct MetricsGen;

impl Gen for MetricsGen {
    type Value = RunMetrics;

    fn generate(&self, rng: &mut Rng) -> RunMetrics {
        RunMetrics {
            app: ["tealeaf", "clvleaf", "lbm", "weather"][rng.index(4)].to_string(),
            policy: gen_name(rng),
            gpu_energy_kj: rng.uniform_range(0.0, 200.0),
            exec_time_s: rng.uniform_range(0.0, 500.0),
            switches: rng.below(1 << 20),
            switch_energy_j: rng.uniform_range(0.0, 10.0),
            switch_time_s: rng.uniform_range(0.0, 1.0),
            cumulative_regret: rng.uniform_range(-50.0, 50.0),
            // Full-width u64 stresses the >2^53 string-integer path.
            steps: rng.next_u64(),
            completed: rng.uniform(),
            qos_violation_frac: if rng.chance(0.5) { Some(rng.uniform()) } else { None },
        }
    }
}

struct AssignmentGen;

impl Gen for AssignmentGen {
    type Value = NodeAssignment;

    fn generate(&self, rng: &mut Rng) -> NodeAssignment {
        NodeAssignment {
            node: rng.index(10_624),
            app: ["tealeaf", "clvleaf", "lbm", "miniswp"][rng.index(4)].to_string(),
            seed: rng.next_u64(),
            max_steps: if rng.chance(0.5) { Some(rng.next_u64()) } else { None },
            policy: if rng.chance(0.5) { Some(PolicyGen.generate(rng)) } else { None },
            switch_cost: if rng.chance(0.5) {
                Some(SwitchCost {
                    latency_s: rng.uniform_range(0.0, 0.001),
                    energy_j: rng.uniform_range(0.0, 2.0),
                })
            } else {
                None
            },
            freqs_ghz: if rng.chance(0.25) {
                // Ascending positive arm sets (a valid domain is what the
                // leader would have validated before encoding).
                let k = 1 + rng.index(12);
                let mut f = 0.0;
                Some(
                    (0..k)
                        .map(|_| {
                            f += rng.uniform_range(0.05, 0.4);
                            f
                        })
                        .collect(),
                )
            } else {
                None
            },
        }
    }
}

struct EventGen;

impl Gen for EventGen {
    type Value = WorkerEvent;

    fn generate(&self, rng: &mut Rng) -> WorkerEvent {
        if rng.chance(0.5) {
            WorkerEvent::Progress {
                node: rng.index(512),
                completed: rng.uniform(),
                energy_j: rng.uniform_range(0.0, 1e6),
            }
        } else {
            let node = rng.index(512);
            WorkerEvent::Done {
                node,
                result: NodeResult {
                    node,
                    app: "tealeaf".to_string(),
                    metrics: MetricsGen.generate(rng),
                },
            }
        }
    }
}

#[test]
fn node_assignments_round_trip_through_jsonl() {
    forall_seeded(0xA551_617E, 300, AssignmentGen, |a| {
        let line = Frame::Assign(a.clone()).encode_line();
        if line.contains('\n') {
            return false; // JSONL framing demands one line per frame
        }
        matches!(Frame::decode_line(&line), Ok(Frame::Assign(b)) if b == *a)
    });
}

#[test]
fn worker_events_round_trip_through_jsonl() {
    forall_seeded(0xE7E27, 300, EventGen, |ev| {
        let line = Frame::Event(ev.clone()).encode_line();
        matches!(Frame::decode_line(&line), Ok(Frame::Event(e)) if e == *ev)
    });
}

#[test]
fn run_metrics_round_trip_exactly_in_both_render_forms() {
    forall_seeded(0x3E721C5, 300, MetricsGen, |m| {
        let j = m.to_wire();
        let Ok(compact) = Json::parse(&j.render_compact()) else { return false };
        let Ok(pretty) = Json::parse(&j.render()) else { return false };
        RunMetrics::from_wire(&compact) == Ok(m.clone())
            && RunMetrics::from_wire(&pretty) == Ok(m.clone())
    });
}

#[test]
fn config_frames_round_trip_with_every_policy() {
    forall_seeded(0xC0F16, 200, PolicyGen, |p| {
        let session = SessionCfg {
            seed: 0xDEAD_BEEF_DEAD_BEEF, // > 2^53: string-integer path
            max_steps: (1 << 60) + 7,
            ..SessionCfg::default()
        };
        let f = Frame::Config {
            jobs: 7,
            heartbeat_steps: 1_234,
            policy: p.clone(),
            session,
        };
        matches!(Frame::decode_line(&f.encode_line()), Ok(g) if g == f)
    });
}

#[test]
fn every_truncated_frame_prefix_is_rejected() {
    let mut rng = Rng::new(0x7A0);
    for _ in 0..25 {
        let a = AssignmentGen.generate(&mut rng);
        let line = Frame::Assign(a).encode_line();
        // A frame is a single top-level object, so no proper prefix can
        // be a complete document: every one must error (not panic).
        for cut in 0..line.len() {
            if !line.is_char_boundary(cut) {
                continue;
            }
            assert!(
                Frame::decode_line(&line[..cut]).is_err(),
                "prefix of len {cut} decoded: {:?}",
                &line[..cut]
            );
        }
    }
}

#[test]
fn malformed_frames_are_rejected_without_panicking() {
    for bad in [
        "",
        "   ",
        "null",
        "42",
        "\"frame\"",
        "[{\"frame\":\"run\"}]",
        "{\"frame\":\"run\"}{\"frame\":\"run\"}",
        "{\"frame\":\"assign\"}",
        "{\"frame\":\"assign\",\"assignment\":{\"node\":\"zero\"}}",
        "{\"frame\":\"event\",\"payload\":{\"event\":\"explode\"}}",
        "{\"frame\":\"config\",\"jobs\":2}",
        "{\"frame\":\"end\",\"nodes\":-3}",
        "{\"frame\":\"end\",\"nodes\":2.5}",
        "{\"frame\":\"end\",\"nodes\":1e99}",
    ] {
        assert!(Frame::decode_line(bad).is_err(), "{bad:?}");
    }
}

#[test]
fn json_reader_survives_random_noise_and_deep_nesting() {
    let alphabet: Vec<char> =
        "{}[]\",:0123456789.eE+-nulltruefalse\\ é☃".chars().collect();
    let mut rng = Rng::new(0xF422);
    for _ in 0..2_000 {
        let len = rng.index(80);
        let s: String = (0..len).map(|_| alphabet[rng.index(alphabet.len())]).collect();
        let _ = Json::parse(&s); // must return (Ok or Err), never panic
        let _ = Frame::decode_line(&s);
    }
    // Pathological nesting errors out instead of blowing the stack.
    for deep in ["[", "{\"k\":[", "[{\"k\":"] {
        let bomb = deep.repeat(50_000);
        assert!(Json::parse(&bomb).is_err());
    }
}

/// Random JSON trees round-trip through both renderers — the substrate
/// guarantee every codec above builds on.
struct JsonGen {
    depth: usize,
}

fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    let variants = if depth == 0 { 4 } else { 6 };
    match rng.index(variants) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            let x = rng.uniform_range(-1e9, 1e9);
            Json::Num(if rng.chance(0.5) { x.trunc() } else { x })
        }
        3 => Json::Str(gen_name(rng)),
        4 => Json::Arr((0..rng.index(4)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Json::obj();
            for i in 0..rng.index(4) {
                obj.set(format!("k{i}"), gen_json(rng, depth - 1));
            }
            obj
        }
    }
}

impl Gen for JsonGen {
    type Value = Json;

    fn generate(&self, rng: &mut Rng) -> Json {
        gen_json(rng, self.depth)
    }
}

#[test]
fn json_trees_round_trip_through_both_renderers() {
    forall_seeded(0x150E57, 400, JsonGen { depth: 3 }, |j| {
        Json::parse(&j.render()).as_ref() == Ok(j)
            && Json::parse(&j.render_compact()).as_ref() == Ok(j)
    });
}
