//! Policy-contract conformance suite, run over every `Policy` and
//! `BatchPolicy` implementation (proptest_lite-driven):
//!
//! 1. `reset()` restores fresh-run behavior byte-for-byte — identical
//!    selection trajectories before and after a reset on an identical
//!    reward stream (this pins the RNG-reseeding contract for the
//!    stochastic policies).
//! 2. Selection is deterministic given the construction seed — two
//!    identically-built instances produce identical trajectories.
//! 3. A B = 1 batch reproduces the scalar policy on identical reward
//!    streams: bit-for-bit for the f64 SoA implementations (UCB1, SW-UCB,
//!    ε-greedy) and for the `Scalar` bridge of every scalar policy; the
//!    f32 SA-UCB core (EnergyUCB) is pinned to within f32 index
//!    resolution (disagreements are only legal at a near-tie of the
//!    scalar index, and pull counts must still agree exactly).
//!
//! DRLCap is deliberately excluded: its `reset()` is mode-dependent by
//! design (CrossDeploy keeps the pre-trained network), so the byte-for-byte
//! contract does not apply; its determinism is covered by its own tests.

use energyucb::bandit::batch::{
    BatchEnergyUcb, BatchEpsilonGreedy, BatchPolicy, BatchSwUcb, BatchUcb1, SaUcbHyper, Scalar,
};
use energyucb::bandit::{
    BatchCLinUcb, BatchLinUcb, CLinUcb, ConstrainedEnergyUcb, EnergyTs, EnergyUcb,
    EnergyUcbConfig, EpsilonGreedy, InitStrategy, LinUcb, Oracle, Policy, RoundRobin,
    SlidingWindowUcb, StaticPolicy, Ucb1, CONTEXT_DIM,
};
use energyucb::rl::RlPower;
use energyucb::testutil::proptest_lite::{forall_seeded, Gen};
use energyucb::util::Rng;

/// Every scalar policy under contract, built for `k` arms from `seed`.
fn factories() -> Vec<(&'static str, fn(usize, u64) -> Box<dyn Policy>)> {
    vec![
        ("energyucb", |k, _s| Box::new(EnergyUcb::new(k, EnergyUcbConfig::default()))),
        ("energyucb-warmup", |k, _s| {
            Box::new(EnergyUcb::new(
                k,
                EnergyUcbConfig { init: InitStrategy::WarmupRoundRobin, ..Default::default() },
            ))
        }),
        ("energyucb-discounted", |k, _s| {
            Box::new(EnergyUcb::new(k, EnergyUcbConfig { discount: 0.99, ..Default::default() }))
        }),
        ("constrained", |k, _s| {
            Box::new(ConstrainedEnergyUcb::new(k, EnergyUcbConfig::default(), 0.1))
        }),
        ("ucb1", |k, _s| Box::new(Ucb1::new(k, 0.05))),
        ("swucb", |k, _s| Box::new(SlidingWindowUcb::new(k, 0.05, 0.01, 64))),
        ("egreedy", |k, s| Box::new(EpsilonGreedy::new(k, 0.1, 10.0, s))),
        ("energyts", |k, s| Box::new(EnergyTs::default_for(k, s))),
        ("rrfreq", |k, _s| Box::new(RoundRobin::new(k))),
        ("static", |k, _s| Box::new(StaticPolicy::new(k, k - 1))),
        ("oracle", |k, _s| {
            Box::new(Oracle::from_true_rewards(
                &(0..k).map(|i| -1.0 - 0.05 * i as f64).collect::<Vec<_>>(),
            ))
        }),
        ("rlpower", |k, s| Box::new(RlPower::new(k, s))),
        // Contextual policies under the same contract: drive_scalar never
        // feeds context, exercising their context-free (bias-only) path.
        ("linucb", |k, _s| Box::new(LinUcb::new(k, CONTEXT_DIM, 1.0, 1.0))),
        ("clinucb", |k, _s| Box::new(CLinUcb::new(k, CONTEXT_DIM, 1.0, 1.0, 0.1))),
    ]
}

/// Drive a scalar policy for `steps` on the deterministic reward stream
/// keyed by `stream_seed`; returns the selection trajectory. One RNG draw
/// per step regardless of the arm chosen, so two passes stay comparable.
fn drive_scalar(p: &mut dyn Policy, k: usize, steps: u64, stream_seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(stream_seed);
    let mut out = Vec::with_capacity(steps as usize);
    for t in 1..=steps {
        let arm = p.select(t);
        assert!(arm < k, "arm {arm} out of range (k={k})");
        let reward = -(1.0 + 0.05 * arm as f64) + 0.05 * rng.gaussian();
        let progress = 1e-3 * (1.0 + arm as f64 / k as f64);
        p.update(arm, reward, progress);
        out.push(arm);
    }
    out
}

struct SeedK;

impl Gen for SeedK {
    type Value = (u64, usize);
    fn generate(&self, rng: &mut Rng) -> (u64, usize) {
        (rng.next_u64(), 3 + rng.index(7)) // k in 3..=9
    }
}

#[test]
fn reset_restores_fresh_run_byte_for_byte() {
    forall_seeded(0xC0_0001, 15, SeedK, |(seed, k)| {
        factories().into_iter().all(|(name, mk)| {
            let mut p = mk(*k, *seed);
            let first = drive_scalar(p.as_mut(), *k, 250, seed ^ 0xABCD);
            p.reset();
            let second = drive_scalar(p.as_mut(), *k, 250, seed ^ 0xABCD);
            if first != second {
                eprintln!("reset not byte-for-byte: {name} (k={k}, seed={seed:#x})");
                return false;
            }
            true
        })
    });
}

#[test]
fn selection_is_deterministic_given_seed() {
    forall_seeded(0xC0_0002, 15, SeedK, |(seed, k)| {
        factories().into_iter().all(|(name, mk)| {
            let mut a = mk(*k, *seed);
            let mut b = mk(*k, *seed);
            let ta = drive_scalar(a.as_mut(), *k, 250, seed ^ 0x1234);
            let tb = drive_scalar(b.as_mut(), *k, 250, seed ^ 0x1234);
            if ta != tb {
                eprintln!("non-deterministic: {name} (k={k}, seed={seed:#x})");
                return false;
            }
            true
        })
    });
}

/// Drive a B = 1 batch policy and a scalar policy side by side on the
/// identical reward stream; returns false at the first selection mismatch.
fn pair_runs_identically(
    batch: &mut dyn BatchPolicy,
    scalar: &mut dyn Policy,
    k: usize,
    steps: u64,
    stream_seed: u64,
) -> bool {
    let ones = vec![1.0f32; k];
    let mut sel = [0i32; 1];
    let mut rng = Rng::new(stream_seed);
    for t in 1..=steps {
        batch.select_into(t, &ones, &mut sel);
        let s_b = sel[0] as usize;
        let s_s = scalar.select(t);
        if s_b != s_s {
            return false;
        }
        let reward = -(1.0 + 0.05 * s_b as f64) + 0.05 * rng.gaussian();
        let progress = 1e-3 * (1.0 + s_b as f64 / k as f64);
        batch.update_batch(&sel, &[reward], &[progress], &[1.0]);
        scalar.update(s_s, reward, progress);
    }
    true
}

/// The f64 native SoA batch policies reproduce their scalar counterparts
/// bit-for-bit at B = 1.
#[test]
fn batched_b1_equals_scalar_bit_for_bit() {
    forall_seeded(0xC0_0003, 20, SeedK, |(seed, k)| {
        let k = *k;
        let stream = seed ^ 0x5EED;

        let mut ucb_b = BatchUcb1::new(1, k, 0.05);
        let mut ucb_s = Ucb1::new(k, 0.05);
        if !pair_runs_identically(&mut ucb_b, &mut ucb_s, k, 300, stream) {
            eprintln!("ucb1 B=1 != scalar (k={k}, seed={seed:#x})");
            return false;
        }

        let mut sw_b = BatchSwUcb::new(1, k, 0.05, 0.01, 64);
        let mut sw_s = SlidingWindowUcb::new(k, 0.05, 0.01, 64);
        if !pair_runs_identically(&mut sw_b, &mut sw_s, k, 300, stream) {
            eprintln!("swucb B=1 != scalar (k={k}, seed={seed:#x})");
            return false;
        }

        let mut eg_b = BatchEpsilonGreedy::new(1, k, 0.1, 10.0, *seed);
        let mut eg_s = EpsilonGreedy::new(k, 0.1, 10.0, *seed);
        if !pair_runs_identically(&mut eg_b, &mut eg_s, k, 300, stream) {
            eprintln!("egreedy B=1 != scalar (k={k}, seed={seed:#x})");
            return false;
        }

        // Contextual policies on the context-free path: B = 1 batched
        // LinUCB must reproduce the scalar wrapper bit-for-bit too.
        let mut lin_b = BatchLinUcb::new(1, k, CONTEXT_DIM, 1.0, 1.0);
        let mut lin_s = LinUcb::new(k, CONTEXT_DIM, 1.0, 1.0);
        if !pair_runs_identically(&mut lin_b, &mut lin_s, k, 300, stream) {
            eprintln!("linucb B=1 != scalar (k={k}, seed={seed:#x})");
            return false;
        }
        true
    });
}

/// B = 1 batched contextual LinUCB reproduces the scalar wrapper
/// bit-for-bit on the *contextual* select path — the same contract as
/// `batched_b1_equals_scalar_bit_for_bit`, but with a fresh context
/// vector fed to every decision.
#[test]
fn contextual_b1_equals_scalar_bit_for_bit() {
    forall_seeded(0xC0_0007, 20, SeedK, |(seed, k)| {
        let k = *k;
        let pairs: Vec<(Box<dyn BatchPolicy>, Box<dyn Policy>)> = vec![
            (
                Box::new(BatchLinUcb::new(1, k, CONTEXT_DIM, 1.0, 1.0)),
                Box::new(LinUcb::new(k, CONTEXT_DIM, 1.0, 1.0)),
            ),
            (
                Box::new(BatchCLinUcb::new(1, k, CONTEXT_DIM, 1.0, 1.0, 0.1)),
                Box::new(CLinUcb::new(k, CONTEXT_DIM, 1.0, 1.0, 0.1)),
            ),
        ];
        for (mut b, mut s) in pairs {
            let ones = vec![1.0f32; k];
            let mut sel = [0i32; 1];
            let mut rng = Rng::new(seed ^ 0xC7E7);
            for t in 1..=300u64 {
                let ctx: Vec<f64> = (0..CONTEXT_DIM).map(|_| rng.uniform()).collect();
                b.select_into_ctx(t, &ones, &ctx, CONTEXT_DIM, &mut sel);
                let s_b = sel[0] as usize;
                let s_s = s.select_ctx(t, &ctx);
                if s_b != s_s {
                    eprintln!(
                        "{} contextual B=1 != scalar at t={t} (k={k}, seed={seed:#x})",
                        b.name()
                    );
                    return false;
                }
                let reward = -(1.0 + 0.05 * s_b as f64) + 0.05 * rng.gaussian();
                b.update_batch(&sel, &[reward], &[1e-3], &[1.0]);
                s.update(s_s, reward, 1e-3);
            }
        }
        true
    });
}

/// Stationary-context sanity: with a constant context, LinUCB degenerates
/// to a per-arm mean estimator and must converge to the same best arm as
/// UCB1 on a fixed reward gap.
#[test]
fn stationary_context_linucb_converges_like_ucb1() {
    let ctx = [0.5; CONTEXT_DIM];
    let best = |counts: &[u64]| -> usize {
        counts.iter().enumerate().max_by_key(|(_, &c)| c).map(|(i, _)| i).unwrap()
    };
    for seed in [3u64, 11, 42] {
        let k = 5;
        let mut lin = LinUcb::new(k, CONTEXT_DIM, 1.0, 1.0);
        let mut ucb = Ucb1::new(k, 0.05);
        let mut lin_counts = vec![0u64; k];
        let mut ucb_counts = vec![0u64; k];
        let mut rng = Rng::new(seed);
        for t in 1..=3_000u64 {
            // Arm 2 is strictly best; noise is small against the 0.1 gap.
            let reward = |arm: usize, rng: &mut Rng| {
                -(1.0 + 0.1 * (arm as f64 - 2.0).abs()) + 0.02 * rng.gaussian()
            };
            let a = lin.select_ctx(t, &ctx);
            let r = reward(a, &mut rng);
            lin.update(a, r, 1e-3);
            let b = ucb.select(t);
            let r = reward(b, &mut rng);
            ucb.update(b, r, 1e-3);
            if t > 2_000 {
                lin_counts[a] += 1;
                ucb_counts[b] += 1;
            }
        }
        assert_eq!(best(&lin_counts), 2, "linucb missed the best arm (seed {seed})");
        assert_eq!(
            best(&lin_counts),
            best(&ucb_counts),
            "linucb and ucb1 disagree on the best arm (seed {seed})"
        );
    }
}

/// The `Scalar` bridge is a faithful adapter: bridging a policy at B = 1
/// must not perturb its trajectory — for EVERY scalar policy under
/// contract.
#[test]
fn scalar_bridge_b1_is_transparent() {
    forall_seeded(0xC0_0004, 12, SeedK, |(seed, k)| {
        factories().into_iter().all(|(name, mk)| {
            let mut bridged = Scalar::new(vec![mk(*k, *seed)]);
            let mut direct = mk(*k, *seed);
            if !pair_runs_identically(&mut bridged, direct.as_mut(), *k, 250, seed ^ 0xB11D)
            {
                eprintln!("bridge perturbed {name} (k={k}, seed={seed:#x})");
                return false;
            }
            true
        })
    });
}

/// The f32 SA-UCB batch core tracks the f64 scalar EnergyUCB to within
/// f32 index resolution: selections may differ only at a near-tie of the
/// scalar's own top-two index gap, and pull counts agree exactly when the
/// trajectories are re-aligned on the batch's choice.
#[test]
fn batched_b1_energyucb_tracks_scalar_within_f32_resolution() {
    forall_seeded(0xC0_0005, 20, SeedK, |(seed, k)| {
        let k = *k;
        let mut scalar = EnergyUcb::new(k, EnergyUcbConfig::default());
        let mut batch = BatchEnergyUcb::new(1, k, SaUcbHyper::default());
        let ones = vec![1.0f32; k];
        let mut sel = [0i32; 1];
        let mut rng = Rng::new(seed ^ 0xF32);
        for t in 1..=400u64 {
            batch.select_into(t, &ones, &mut sel);
            let s_b = sel[0] as usize;
            let s_s = scalar.select(t);
            if s_b != s_s {
                let mut idx: Vec<f64> = (0..k).map(|i| scalar.sa_ucb(i, t)).collect();
                idx.sort_by(|a, b| b.partial_cmp(a).unwrap());
                if idx[0] - idx[1] > 5e-3 {
                    eprintln!(
                        "energyucb diverged on a clear gap {} at t={t} (k={k}, seed={seed:#x})",
                        idx[0] - idx[1]
                    );
                    return false;
                }
            }
            // Synthesize the reward in f32 (the fleet contract) so the f64
            // handoff is exact, and re-align both on the batch's choice.
            let r = (-(1.0 + 0.03 * s_b as f64) + 0.05 * rng.gaussian()) as f32 as f64;
            batch.update_batch(&sel, &[r], &[1e-3], &[1.0]);
            scalar.update(s_b, r, 1e-3);
        }
        (0..k).all(|i| batch.counts()[i] as f64 == scalar.count(i))
    });
}

/// Batch policies obey the same reset/determinism contract as scalar ones.
#[test]
fn batch_policies_reset_and_determinism() {
    let mk_all = |k: usize, seed: u64| -> Vec<Box<dyn BatchPolicy>> {
        vec![
            Box::new(BatchEnergyUcb::with_initial_arm(3, k, SaUcbHyper::default(), k - 1)),
            Box::new(BatchUcb1::new(3, k, 0.05)),
            Box::new(BatchSwUcb::new(3, k, 0.05, 0.01, 64)),
            Box::new(BatchEpsilonGreedy::new(3, k, 0.1, 10.0, seed)),
            Box::new(Scalar::new(vec![
                EnergyTs::default_for(k, seed),
                EnergyTs::default_for(k, seed ^ 1),
                EnergyTs::default_for(k, seed ^ 2),
            ])),
        ]
    };
    let drive = |p: &mut dyn BatchPolicy, k: usize, stream_seed: u64| -> Vec<i32> {
        let ones = vec![1.0f32; 3 * k];
        let mut sel = vec![0i32; 3];
        let mut rng = Rng::new(stream_seed);
        let mut hist = Vec::new();
        for t in 1..=200u64 {
            p.select_into(t, &ones, &mut sel);
            let rewards: Vec<f64> =
                sel.iter().map(|&s| -(1.0 + 0.05 * s as f64) + 0.05 * rng.gaussian()).collect();
            p.update_batch(&sel, &rewards, &[1e-3; 3], &[1.0; 3]);
            hist.extend_from_slice(&sel);
        }
        hist
    };
    forall_seeded(0xC0_0006, 10, SeedK, |(seed, k)| {
        for mut p in mk_all(*k, *seed) {
            let first = drive(p.as_mut(), *k, seed ^ 0x7777);
            p.reset();
            let second = drive(p.as_mut(), *k, seed ^ 0x7777);
            if first != second {
                eprintln!("batch reset not byte-for-byte: {} (k={k})", p.name());
                return false;
            }
        }
        for (mut a, mut b) in mk_all(*k, *seed).into_iter().zip(mk_all(*k, *seed)) {
            let ta = drive(a.as_mut(), *k, seed ^ 0x8888);
            let tb = drive(b.as_mut(), *k, seed ^ 0x8888);
            if ta != tb {
                eprintln!("batch non-deterministic: {} (k={k})", a.name());
                return false;
            }
        }
        true
    });
}
